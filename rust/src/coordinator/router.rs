//! Request router: the serving front of the coordinator.
//!
//! Jobs (videos to analyze) arrive; the router consults its
//! [`Planner`] for a joint (mode, k) [`Plan`] — fixed-mode (the
//! paper's k-only decision, with optional online optimization and
//! decision caching) or joint mode×k — dispatches to the configured
//! executor, and returns the combined result. Metrics are recorded per
//! job.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::executor::{self, ExperimentResult};
use crate::coordinator::optimizer::{OnlineOptimizer, OptimizerDecision};
use crate::coordinator::planner::{FixedModePlanner, Plan, PlanCacheStats, PlanRequest, Planner};
use crate::device::DeviceSpec;
use crate::metrics::Registry;
use crate::server::allocator::predict_full_device;
use crate::server::shard::ShardSnapshot;
use crate::workload::{TaskProfile, Video};

/// How the fixed-mode planner chooses k.
#[derive(Debug, Clone)]
pub enum SplitPolicy {
    /// Always use this many containers.
    Fixed(usize),
    /// Run the online optimizer once per (device, task) and cache it.
    Online(OnlineOptimizer),
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct InferenceJob {
    pub id: u64,
    pub video: Video,
    pub task: TaskProfile,
}

/// Completed request.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub containers_used: usize,
    pub result: ExperimentResult,
}

/// The coordinator: configuration + planner + metrics.
#[derive(Debug)]
pub struct Coordinator {
    pub base: ExperimentConfig,
    pub metrics: Registry,
    planner: Box<dyn Planner>,
}

impl Coordinator {
    /// Coordinator with the default fixed-mode planner wrapping
    /// `policy` — the pre-redesign behavior.
    pub fn new(base: ExperimentConfig, policy: SplitPolicy) -> Self {
        let planner = Box::new(FixedModePlanner::new(base.clone(), policy));
        Self::with_planner(base, planner)
    }

    /// Coordinator with an explicit planner (e.g.
    /// [`crate::coordinator::planner::JointPlanner`]).
    pub fn with_planner(base: ExperimentConfig, planner: Box<dyn Planner>) -> Self {
        Coordinator { base, metrics: Registry::new(), planner }
    }

    /// The one decision entry point: plan a job described by `req`.
    /// Requests carrying a `current_k` are regrant decisions and
    /// counted as such.
    pub fn plan(&mut self, req: &PlanRequest) -> Result<Plan> {
        if req.current_k.is_some() {
            self.metrics.inc("regrant_decisions", 1);
        }
        self.planner.plan(req)
    }

    /// Build the [`PlanRequest`] for `job` against this coordinator's
    /// device (startup override applied), with the whole device free.
    pub fn request_for(&self, job: &InferenceJob) -> PlanRequest {
        PlanRequest::new(
            self.base.effective_device(),
            job.task.clone(),
            job.video.frame_count(),
        )
    }

    /// The planner's short name (CLI summaries).
    pub fn planner_name(&self) -> &'static str {
        self.planner.name()
    }

    /// The wrapped policy's fixed k, when the planner is the fixed-mode
    /// planner over `SplitPolicy::Fixed` (the retired `decide_k`'s
    /// uncapped fast path, kept by [`Self::submit`]; a joint planner
    /// always plans).
    fn fixed_policy_k(&self) -> Option<usize> {
        self.planner.fixed_policy_k()
    }

    /// Process one job end to end.
    pub fn submit(&mut self, job: InferenceJob) -> Result<JobResult> {
        let k = match self.fixed_policy_k() {
            Some(k) => k,
            None => {
                let req = self.request_for(&job);
                self.plan(&req)?.k
            }
        };
        let mut cfg = self.base.clone();
        cfg.task = job.task.clone();
        cfg.video = job.video.clone();
        cfg.containers = k;

        let t0 = std::time::Instant::now();
        let result = executor::run(&cfg)?;
        let wall = t0.elapsed().as_secs_f64();

        self.metrics.inc("jobs_completed", 1);
        self.metrics.inc("frames_processed", result.frames as u64);
        self.metrics.histogram("job_wall_s").record_s(wall);
        self.metrics.histogram("job_sim_time_s").record_s(result.time_s);
        self.metrics.set_gauge("last_energy_j", result.energy_j);

        Ok(JobResult { id: job.id, containers_used: k, result })
    }

    /// Cached optimizer decisions (for inspection / tests).
    pub fn decisions(&self) -> Vec<(&str, &OptimizerDecision)> {
        self.planner.cached_decisions()
    }

    /// Plan-cache hit/miss/occupancy counters from the planner.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.planner.cache_stats()
    }
}

/// Energy-conscious cross-shard selector: the top level of the sharded
/// fleet's two-level router ([`crate::server::shard`]). It chooses the
/// shard; the shard's own engine then places the job on a node with its
/// configured policy (power-of-two choices at fleet scale).
///
/// The objective is ECORE-style: each shard is scored by the predicted
/// energy of its best device for this job, inflated by the shard's
/// current congestion — `energy * (1 + (queued + routed_this_epoch) /
/// nodes)` — so a cheap pool absorbs load until its backlog erodes the
/// energy advantage. Queue saturation triggers overflow re-routing to
/// the least-loaded unsaturated shard.
///
/// Deterministic by construction: decisions depend only on the static
/// pool profiles, the barrier-time [`ShardSnapshot`]s (collected in
/// shard order) and the in-epoch routing counts — never on thread
/// timing.
#[derive(Debug)]
pub struct ShardRouter {
    pools: Vec<PoolProfile>,
    /// Queue depth (`queued + routed_this_epoch`) at which a shard
    /// stops taking overflow-eligible jobs.
    saturation: usize,
    routed_epoch: Vec<usize>,
    /// Jobs routed per shard over the whole run.
    routed_total: Vec<usize>,
    /// Per-(shard, task, frames) energy estimates. The fleet serves a
    /// handful of task shapes across millions of jobs, so this is
    /// effectively a free lookup after warmup.
    energy_cache: std::collections::HashMap<(usize, usize, u64, u64), f64>,
    /// Jobs re-routed away from their scored-best shard because its
    /// admission queue was saturated.
    pub overflow_reroutes: u64,
    /// Cloud tier the fleet can offload to (`None` = edge-only). Used
    /// by [`Self::cloud_favors`] to decide which jobs are worth
    /// leaving offload-eligible vs pinning to their edge shard.
    tier: Option<crate::net::TierSpec>,
    /// Jobs pinned local because their edge shard already undercut the
    /// billed cloud estimate ([`Self::cloud_favors`] said no).
    pub local_pins: u64,
}

#[derive(Debug)]
struct PoolProfile {
    nodes: usize,
    /// Distinct device types in the pool (deduped by name), for the
    /// per-job energy estimate.
    devices: Vec<DeviceSpec>,
}

impl ShardRouter {
    /// Build from each shard's node list. `saturation` is the queued
    /// depth beyond which a shard overflows (see [`Self::choose`]).
    pub fn new(pools: &[&[DeviceSpec]], saturation: usize) -> Self {
        assert!(!pools.is_empty(), "router needs at least one shard");
        let pools: Vec<PoolProfile> = pools
            .iter()
            .map(|nodes| {
                assert!(!nodes.is_empty(), "empty shard pool");
                let mut devices: Vec<DeviceSpec> = Vec::new();
                for d in *nodes {
                    if !devices.iter().any(|seen| seen.name == d.name) {
                        devices.push(d.clone());
                    }
                }
                PoolProfile { nodes: nodes.len(), devices }
            })
            .collect();
        let n = pools.len();
        ShardRouter {
            pools,
            saturation: saturation.max(1),
            routed_epoch: vec![0; n],
            routed_total: vec![0; n],
            energy_cache: std::collections::HashMap::new(),
            overflow_reroutes: 0,
            tier: None,
            local_pins: 0,
        }
    }

    /// Attach a cloud tier: [`Self::cloud_favors`] starts answering
    /// against its billed energy instead of always `false`.
    pub fn with_tier(mut self, tier: crate::net::TierSpec) -> Self {
        self.tier = Some(tier);
        self
    }

    /// Would the cloud tier plausibly beat shard `s` for this job right
    /// now? Compares the shard's congestion-inflated energy score (the
    /// same objective [`Self::choose`] ranks with) against the billed
    /// full-cloud estimate — remote energy × tier multiplier + link TX.
    /// `false` means the edge shard already wins outright and the job
    /// should be privacy-pinned local, sparing the planner the offload
    /// grid search; `true` leaves it offload-eligible so the joint
    /// planner can search split fractions. Edge-only routers (no tier)
    /// always answer `false`.
    pub fn cloud_favors(
        &mut self,
        s: usize,
        task: &TaskProfile,
        frames: usize,
        load: &[ShardSnapshot],
    ) -> bool {
        let Some(tier) = self.tier.clone() else { return false };
        let edge = self.energy_estimate(s, task, frames);
        let depth = load[s].queued + self.routed_epoch[s];
        let congestion = depth as f64 / self.pools[s].nodes as f64;
        let cloud = predict_full_device(&tier.device, task, frames).1 * tier.energy_mult
            + tier.link.tx_energy_j(frames);
        let favors = cloud < edge * (1.0 + congestion);
        if !favors {
            self.local_pins += 1;
        }
        favors
    }

    /// Pick a shard for a `frames`-sized `task` job given the
    /// barrier-time load snapshots (one per shard, in shard order).
    pub fn choose(
        &mut self,
        task: &TaskProfile,
        frames: usize,
        load: &[ShardSnapshot],
    ) -> usize {
        debug_assert_eq!(load.len(), self.pools.len());
        let mut best = 0usize;
        let mut best_key = (f64::INFINITY, usize::MAX, usize::MAX);
        for s in 0..self.pools.len() {
            let energy = self.energy_estimate(s, task, frames);
            let depth = load[s].queued + self.routed_epoch[s];
            let congestion = depth as f64 / self.pools[s].nodes as f64;
            // Ties (identical pools, identical load) break to the
            // shallower queue, then the lower shard index.
            let key = (energy * (1.0 + congestion), depth, s);
            if key < best_key {
                best_key = key;
                best = s;
            }
        }
        let depth = |me: &Self, s: usize| load[s].queued + me.routed_epoch[s];
        if depth(self, best) >= self.saturation {
            // Overflow: the energy-best shard is saturated. Re-route to
            // the unsaturated shard with the lowest per-node depth (if
            // every shard is saturated, stay — the backlog is global).
            let alt = (0..self.pools.len())
                .filter(|&s| depth(self, s) < self.saturation)
                .min_by(|&a, &b| {
                    let da = depth(self, a) as f64 / self.pools[a].nodes as f64;
                    let db = depth(self, b) as f64 / self.pools[b].nodes as f64;
                    da.partial_cmp(&db).unwrap().then(a.cmp(&b))
                });
            if let Some(alt) = alt {
                self.overflow_reroutes += 1;
                best = alt;
            }
        }
        self.routed_epoch[best] += 1;
        self.routed_total[best] += 1;
        best
    }

    /// Reset the in-epoch routing counts (fresh snapshots supersede
    /// them at the next barrier).
    pub fn end_epoch(&mut self) {
        self.routed_epoch.iter_mut().for_each(|c| *c = 0);
    }

    /// Jobs routed to each shard over the run so far.
    pub fn routed_per_shard(&self) -> &[usize] {
        &self.routed_total
    }

    /// Best-case (whole-device) predicted energy for this job in shard
    /// `s`: the minimum over the pool's distinct device types.
    fn energy_estimate(&mut self, s: usize, task: &TaskProfile, frames: usize) -> f64 {
        let key = (s, frames, task.flops_per_frame, task.relative_cost.to_bits());
        if let Some(&e) = self.energy_cache.get(&key) {
            return e;
        }
        let e = self.pools[s]
            .devices
            .iter()
            .map(|d| predict_full_device(d, task, frames).1)
            .fold(f64::INFINITY, f64::min);
        self.energy_cache.insert(key, e);
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, frames: usize) -> InferenceJob {
        InferenceJob {
            id,
            video: Video::with_frames("job", frames, 24.0),
            task: TaskProfile::yolo_tiny(),
        }
    }

    /// Plan a job under a grant and return k — the migrated form of the
    /// old `decide_k_constrained` call sites.
    fn plan_k(c: &mut Coordinator, j: &InferenceJob, cores: f64, mem: f64) -> usize {
        let req = c.request_for(j).with_grant(cores, mem);
        c.plan(&req).unwrap().k
    }

    #[test]
    fn fixed_policy_uses_k() {
        let mut c = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(4));
        let r = c.submit(job(1, 240)).unwrap();
        assert_eq!(r.containers_used, 4);
        assert_eq!(r.result.frames, 240);
        assert_eq!(c.metrics.counter("jobs_completed"), 1);
        assert_eq!(c.metrics.counter("frames_processed"), 240);
    }

    #[test]
    fn online_policy_caches_decision() {
        let mut c = Coordinator::new(
            ExperimentConfig::default(),
            SplitPolicy::Online(OnlineOptimizer::default()),
        );
        let r1 = c.submit(job(1, 120)).unwrap();
        assert_eq!(c.decisions().len(), 1);
        let r2 = c.submit(job(2, 120)).unwrap();
        assert_eq!(c.decisions().len(), 1, "decision must be cached");
        assert_eq!(r1.containers_used, r2.containers_used);
    }

    #[test]
    fn online_decision_beats_naive_single_container() {
        let mut online = Coordinator::new(
            ExperimentConfig::default(),
            SplitPolicy::Online(OnlineOptimizer::default()),
        );
        let mut naive =
            Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(1));
        let r_online = online.submit(job(1, 720)).unwrap();
        let r_naive = naive.submit(job(1, 720)).unwrap();
        assert!(
            r_online.result.energy_j < r_naive.result.energy_j,
            "online {} should beat naive {}",
            r_online.result.energy_j,
            r_naive.result.energy_j
        );
        assert!(r_online.result.time_s < r_naive.result.time_s);
    }

    #[test]
    fn constrained_fixed_k_is_sized_to_the_grant() {
        let mut c = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(4));
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        // whole TX2 free: the paper's unconstrained k
        assert_eq!(plan_k(&mut c, &j, 4.0, mem), 4);
        // half the device granted: k shrinks to the cores granted
        assert_eq!(plan_k(&mut c, &j, 2.0, mem), 2);
        // memory nearly exhausted by co-resident jobs: k shrinks further
        assert_eq!(plan_k(&mut c, &j, 4.0, 1000.0), 1);
    }

    #[test]
    fn full_device_allows_oversubscribed_fixed_k() {
        // With the whole device free the paper's k > cores experiments
        // must still be expressible (memory permitting).
        let mut c = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(6));
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        assert_eq!(plan_k(&mut c, &j, 4.0, mem), 6);
    }

    #[test]
    fn constrained_online_decision_caps_and_caches() {
        let mut base = ExperimentConfig::default();
        base.device = crate::device::DeviceSpec::orin();
        let mut c = Coordinator::new(base, SplitPolicy::Online(OnlineOptimizer::default()));
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        let k_capped = plan_k(&mut c, &j, 4.0, mem);
        assert!(k_capped <= 4, "k={k_capped}");
        let n_decisions = c.decisions().len();
        let again = plan_k(&mut c, &j, 4.0, mem);
        assert_eq!(again, k_capped);
        assert_eq!(c.decisions().len(), n_decisions, "same grant must hit the cache");
        let k_full = plan_k(&mut c, &j, 12.0, mem);
        assert!(k_full >= k_capped, "full {k_full} vs capped {k_capped}");
    }

    #[test]
    fn tiny_grant_skips_probing_and_saturates() {
        let mut c = Coordinator::new(
            ExperimentConfig::default(),
            SplitPolicy::Online(OnlineOptimizer::default()),
        );
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        assert_eq!(plan_k(&mut c, &j, 2.0, mem), 2);
        assert_eq!(plan_k(&mut c, &j, 1.0, mem), 1);
        assert!(c.decisions().is_empty(), "tiny grants must not probe");
    }

    #[test]
    fn regrant_decision_is_sticky_and_counted() {
        let mut base = ExperimentConfig::default();
        base.device = crate::device::DeviceSpec::orin();
        let mut c = Coordinator::new(base, SplitPolicy::Online(OnlineOptimizer::default()));
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        // Admission decides k on a half-device grant; the device then
        // drains and the job is regranted the whole thing. Whatever k
        // it holds is kept when the model says it's near-optimal or
        // the grant is too small to probe.
        let k0 = plan_k(&mut c, &j, 6.0, mem);
        let req = c.request_for(&j).with_grant(2.0, mem).preferring(k0);
        let k_tiny = c.plan(&req).unwrap().k;
        assert!(k_tiny >= 1 && k_tiny <= 2.max(k0));
        assert_eq!(c.metrics.counter("regrant_decisions"), 1);
        // Fixed policy: regrant is just the constrained decision again.
        let mut f = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(4));
        let req = f
            .request_for(&j)
            .with_grant(2.0, f.base.device.memory.available_mib())
            .preferring(4);
        assert_eq!(f.plan(&req).unwrap().k, 2);
    }

    #[test]
    fn different_tasks_get_separate_decisions() {
        let mut c = Coordinator::new(
            ExperimentConfig::default(),
            SplitPolicy::Online(OnlineOptimizer::default()),
        );
        c.submit(job(1, 120)).unwrap();
        c.submit(InferenceJob {
            id: 2,
            video: Video::with_frames("j", 120, 24.0),
            task: TaskProfile::simple_cnn(),
        })
        .unwrap();
        assert_eq!(c.decisions().len(), 2);
    }

    fn idle_snapshot(nodes: usize, cores_per_node: f64) -> ShardSnapshot {
        ShardSnapshot {
            queued: 0,
            resident: 0,
            free_cores: nodes as f64 * cores_per_node,
            total_cores: nodes as f64 * cores_per_node,
            energy_j: 0.0,
            des_events: 0,
        }
    }

    #[test]
    fn shard_router_prefers_the_energy_best_pool_at_equal_load() {
        let orin = vec![crate::device::DeviceSpec::orin(); 4];
        let tx2 = vec![crate::device::DeviceSpec::tx2(); 4];
        let task = TaskProfile::yolo_tiny();
        let e_orin = predict_full_device(&orin[0], &task, 96).1;
        let e_tx2 = predict_full_device(&tx2[0], &task, 96).1;
        assert_ne!(e_orin, e_tx2, "pools must differ for this test to bite");
        let cheaper = if e_orin < e_tx2 { 0 } else { 1 };
        let mut r = ShardRouter::new(&[&orin[..], &tx2[..]], 1_000);
        let load = vec![idle_snapshot(4, 12.0), idle_snapshot(4, 4.0)];
        assert_eq!(r.choose(&task, 96, &load), cheaper);
        // The estimate is cached after the first probe.
        assert_eq!(r.choose(&task, 96, &load), cheaper);
        assert_eq!(r.routed_per_shard()[cheaper], 2);
    }

    #[test]
    fn shard_router_congestion_erodes_the_energy_advantage() {
        // Two identical pools: ties break to shard 0, but every routed
        // job raises its congestion term, so a burst spreads over both.
        let pool = vec![crate::device::DeviceSpec::orin(); 2];
        let mut r = ShardRouter::new(&[&pool[..], &pool[..]], 1_000);
        let load = vec![idle_snapshot(2, 12.0), idle_snapshot(2, 12.0)];
        let task = TaskProfile::yolo_tiny();
        for _ in 0..10 {
            r.choose(&task, 96, &load);
        }
        assert_eq!(r.routed_per_shard(), &[5, 5], "identical pools must split evenly");
        // New epoch, new counts: the in-epoch pressure resets.
        r.end_epoch();
        assert_eq!(r.choose(&task, 96, &load), 0);
    }

    #[test]
    fn shard_router_overflows_a_saturated_shard() {
        // Same device type in both pools (equal energy) so the outcome
        // is pinned by load terms alone: the big pool's low per-node
        // congestion makes it the scored favorite, and once its depth
        // crosses the saturation bar the router must overflow to the
        // small pool while IT still has room — and stop once both are
        // saturated.
        let small = vec![crate::device::DeviceSpec::orin(); 1];
        let big = vec![crate::device::DeviceSpec::orin(); 4];
        let mut r = ShardRouter::new(&[&small[..], &big[..]], 3);
        let load = vec![idle_snapshot(1, 12.0), idle_snapshot(4, 12.0)];
        let task = TaskProfile::yolo_tiny();
        let picks: Vec<usize> = (0..8).map(|_| r.choose(&task, 96, &load)).collect();
        assert!(picks.contains(&0) && picks.contains(&1), "{picks:?}");
        assert!(r.overflow_reroutes > 0, "saturating the favorite must re-route");
        // Overflow re-routing never pushes a shard past saturation
        // while an alternative has room: the small pool stops at the
        // bar (its own picks + reroutes), the rest lands on the big one.
        assert!(r.routed_per_shard()[0] <= 3, "{:?}", r.routed_per_shard());
        assert_eq!(r.routed_per_shard().iter().sum::<usize>(), 8);
    }

    #[test]
    fn cloud_favors_only_congested_shards_and_respects_the_bill() {
        use crate::net::{LinkSpec, TierSpec};
        let pool = vec![crate::device::DeviceSpec::orin(); 2];
        let task = TaskProfile::yolo_tiny();
        let tier = TierSpec::parse("orin", LinkSpec::zero_cost()).unwrap();
        let mut r = ShardRouter::new(&[&pool[..]], 1_000).with_tier(tier);
        // Idle pool of the same device: the cloud only ties, the edge
        // wins outright and the job gets pinned.
        let idle = vec![idle_snapshot(2, 12.0)];
        assert!(!r.cloud_favors(0, &task, 96, &idle));
        assert_eq!(r.local_pins, 1);
        // A backlog inflates the edge score past the cloud bill.
        let mut busy = idle_snapshot(2, 12.0);
        busy.queued = 4;
        assert!(r.cloud_favors(0, &task, 96, &[busy.clone()]));
        assert_eq!(r.local_pins, 1, "favorable answers must not count as pins");
        // A 10x-billed cloud loses even to the congested shard.
        let dear = TierSpec::parse("orin*10", LinkSpec::zero_cost()).unwrap();
        let mut r10 = ShardRouter::new(&[&pool[..]], 1_000).with_tier(dear);
        assert!(!r10.cloud_favors(0, &task, 96, &[busy]));
        // Edge-only routers always answer no and never count pins.
        let mut edge_only = ShardRouter::new(&[&pool[..]], 1_000);
        assert!(!edge_only.cloud_favors(0, &task, 96, &idle));
        assert_eq!(edge_only.local_pins, 0);
    }

    #[test]
    fn submit_keeps_the_uncapped_fixed_k_fast_path() {
        // The retired `decide_k` wrapper returned a fixed policy's k
        // uncapped, leaving run-time memory checks to reject
        // overcommitted runs — submit() preserves that quirk: a k=9 TX2
        // job launches 9 containers and fails in the container layer,
        // not in the planner.
        let mut over = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(9));
        let err = over.submit(job(2, 720)).unwrap_err();
        assert!(format!("{err:#}").contains("exceed"), "{err:#}");
        // The plan surface, by contrast, caps to the memory grant.
        let j = job(3, 720);
        let req = over.request_for(&j);
        assert!(over.plan(&req).unwrap().k <= 6);
    }
}
