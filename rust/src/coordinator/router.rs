//! Request router: the serving front of the coordinator.
//!
//! Jobs (videos to analyze) arrive; the router consults its
//! [`Planner`] for a joint (mode, k) [`Plan`] — fixed-mode (the
//! paper's k-only decision, with optional online optimization and
//! decision caching) or joint mode×k — dispatches to the configured
//! executor, and returns the combined result. Metrics are recorded per
//! job.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::executor::{self, ExperimentResult};
use crate::coordinator::optimizer::{OnlineOptimizer, OptimizerDecision};
use crate::coordinator::planner::{FixedModePlanner, Plan, PlanCacheStats, PlanRequest, Planner};
use crate::metrics::Registry;
use crate::workload::{TaskProfile, Video};

/// How the fixed-mode planner chooses k.
#[derive(Debug, Clone)]
pub enum SplitPolicy {
    /// Always use this many containers.
    Fixed(usize),
    /// Run the online optimizer once per (device, task) and cache it.
    Online(OnlineOptimizer),
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct InferenceJob {
    pub id: u64,
    pub video: Video,
    pub task: TaskProfile,
}

/// Completed request.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub containers_used: usize,
    pub result: ExperimentResult,
}

/// The coordinator: configuration + planner + metrics.
#[derive(Debug)]
pub struct Coordinator {
    pub base: ExperimentConfig,
    pub metrics: Registry,
    planner: Box<dyn Planner>,
}

impl Coordinator {
    /// Coordinator with the default fixed-mode planner wrapping
    /// `policy` — the pre-redesign behavior.
    pub fn new(base: ExperimentConfig, policy: SplitPolicy) -> Self {
        let planner = Box::new(FixedModePlanner::new(base.clone(), policy));
        Self::with_planner(base, planner)
    }

    /// Coordinator with an explicit planner (e.g.
    /// [`crate::coordinator::planner::JointPlanner`]).
    pub fn with_planner(base: ExperimentConfig, planner: Box<dyn Planner>) -> Self {
        Coordinator { base, metrics: Registry::new(), planner }
    }

    /// The one decision entry point: plan a job described by `req`.
    /// Requests carrying a `current_k` are regrant decisions and
    /// counted as such.
    pub fn plan(&mut self, req: &PlanRequest) -> Result<Plan> {
        if req.current_k.is_some() {
            self.metrics.inc("regrant_decisions", 1);
        }
        self.planner.plan(req)
    }

    /// Build the [`PlanRequest`] for `job` against this coordinator's
    /// device (startup override applied), with the whole device free.
    pub fn request_for(&self, job: &InferenceJob) -> PlanRequest {
        PlanRequest::new(
            self.base.effective_device(),
            job.task.clone(),
            job.video.frame_count(),
        )
    }

    /// The planner's short name (CLI summaries).
    pub fn planner_name(&self) -> &'static str {
        self.planner.name()
    }

    /// The wrapped policy's fixed k, when the planner is the fixed-mode
    /// planner over `SplitPolicy::Fixed` (the retired `decide_k`'s
    /// uncapped fast path, kept by [`Self::submit`]; a joint planner
    /// always plans).
    fn fixed_policy_k(&self) -> Option<usize> {
        self.planner.fixed_policy_k()
    }

    /// Process one job end to end.
    pub fn submit(&mut self, job: InferenceJob) -> Result<JobResult> {
        let k = match self.fixed_policy_k() {
            Some(k) => k,
            None => {
                let req = self.request_for(&job);
                self.plan(&req)?.k
            }
        };
        let mut cfg = self.base.clone();
        cfg.task = job.task.clone();
        cfg.video = job.video.clone();
        cfg.containers = k;

        let t0 = std::time::Instant::now();
        let result = executor::run(&cfg)?;
        let wall = t0.elapsed().as_secs_f64();

        self.metrics.inc("jobs_completed", 1);
        self.metrics.inc("frames_processed", result.frames as u64);
        self.metrics.histogram("job_wall_s").record_s(wall);
        self.metrics.histogram("job_sim_time_s").record_s(result.time_s);
        self.metrics.set_gauge("last_energy_j", result.energy_j);

        Ok(JobResult { id: job.id, containers_used: k, result })
    }

    /// Cached optimizer decisions (for inspection / tests).
    pub fn decisions(&self) -> Vec<(&str, &OptimizerDecision)> {
        self.planner.cached_decisions()
    }

    /// Plan-cache hit/miss/occupancy counters from the planner.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.planner.cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, frames: usize) -> InferenceJob {
        InferenceJob {
            id,
            video: Video::with_frames("job", frames, 24.0),
            task: TaskProfile::yolo_tiny(),
        }
    }

    /// Plan a job under a grant and return k — the migrated form of the
    /// old `decide_k_constrained` call sites.
    fn plan_k(c: &mut Coordinator, j: &InferenceJob, cores: f64, mem: f64) -> usize {
        let req = c.request_for(j).with_grant(cores, mem);
        c.plan(&req).unwrap().k
    }

    #[test]
    fn fixed_policy_uses_k() {
        let mut c = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(4));
        let r = c.submit(job(1, 240)).unwrap();
        assert_eq!(r.containers_used, 4);
        assert_eq!(r.result.frames, 240);
        assert_eq!(c.metrics.counter("jobs_completed"), 1);
        assert_eq!(c.metrics.counter("frames_processed"), 240);
    }

    #[test]
    fn online_policy_caches_decision() {
        let mut c = Coordinator::new(
            ExperimentConfig::default(),
            SplitPolicy::Online(OnlineOptimizer::default()),
        );
        let r1 = c.submit(job(1, 120)).unwrap();
        assert_eq!(c.decisions().len(), 1);
        let r2 = c.submit(job(2, 120)).unwrap();
        assert_eq!(c.decisions().len(), 1, "decision must be cached");
        assert_eq!(r1.containers_used, r2.containers_used);
    }

    #[test]
    fn online_decision_beats_naive_single_container() {
        let mut online = Coordinator::new(
            ExperimentConfig::default(),
            SplitPolicy::Online(OnlineOptimizer::default()),
        );
        let mut naive =
            Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(1));
        let r_online = online.submit(job(1, 720)).unwrap();
        let r_naive = naive.submit(job(1, 720)).unwrap();
        assert!(
            r_online.result.energy_j < r_naive.result.energy_j,
            "online {} should beat naive {}",
            r_online.result.energy_j,
            r_naive.result.energy_j
        );
        assert!(r_online.result.time_s < r_naive.result.time_s);
    }

    #[test]
    fn constrained_fixed_k_is_sized_to_the_grant() {
        let mut c = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(4));
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        // whole TX2 free: the paper's unconstrained k
        assert_eq!(plan_k(&mut c, &j, 4.0, mem), 4);
        // half the device granted: k shrinks to the cores granted
        assert_eq!(plan_k(&mut c, &j, 2.0, mem), 2);
        // memory nearly exhausted by co-resident jobs: k shrinks further
        assert_eq!(plan_k(&mut c, &j, 4.0, 1000.0), 1);
    }

    #[test]
    fn full_device_allows_oversubscribed_fixed_k() {
        // With the whole device free the paper's k > cores experiments
        // must still be expressible (memory permitting).
        let mut c = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(6));
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        assert_eq!(plan_k(&mut c, &j, 4.0, mem), 6);
    }

    #[test]
    fn constrained_online_decision_caps_and_caches() {
        let mut base = ExperimentConfig::default();
        base.device = crate::device::DeviceSpec::orin();
        let mut c = Coordinator::new(base, SplitPolicy::Online(OnlineOptimizer::default()));
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        let k_capped = plan_k(&mut c, &j, 4.0, mem);
        assert!(k_capped <= 4, "k={k_capped}");
        let n_decisions = c.decisions().len();
        let again = plan_k(&mut c, &j, 4.0, mem);
        assert_eq!(again, k_capped);
        assert_eq!(c.decisions().len(), n_decisions, "same grant must hit the cache");
        let k_full = plan_k(&mut c, &j, 12.0, mem);
        assert!(k_full >= k_capped, "full {k_full} vs capped {k_capped}");
    }

    #[test]
    fn tiny_grant_skips_probing_and_saturates() {
        let mut c = Coordinator::new(
            ExperimentConfig::default(),
            SplitPolicy::Online(OnlineOptimizer::default()),
        );
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        assert_eq!(plan_k(&mut c, &j, 2.0, mem), 2);
        assert_eq!(plan_k(&mut c, &j, 1.0, mem), 1);
        assert!(c.decisions().is_empty(), "tiny grants must not probe");
    }

    #[test]
    fn regrant_decision_is_sticky_and_counted() {
        let mut base = ExperimentConfig::default();
        base.device = crate::device::DeviceSpec::orin();
        let mut c = Coordinator::new(base, SplitPolicy::Online(OnlineOptimizer::default()));
        let j = job(1, 96);
        let mem = c.base.device.memory.available_mib();
        // Admission decides k on a half-device grant; the device then
        // drains and the job is regranted the whole thing. Whatever k
        // it holds is kept when the model says it's near-optimal or
        // the grant is too small to probe.
        let k0 = plan_k(&mut c, &j, 6.0, mem);
        let req = c.request_for(&j).with_grant(2.0, mem).preferring(k0);
        let k_tiny = c.plan(&req).unwrap().k;
        assert!(k_tiny >= 1 && k_tiny <= 2.max(k0));
        assert_eq!(c.metrics.counter("regrant_decisions"), 1);
        // Fixed policy: regrant is just the constrained decision again.
        let mut f = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(4));
        let req = f
            .request_for(&j)
            .with_grant(2.0, f.base.device.memory.available_mib())
            .preferring(4);
        assert_eq!(f.plan(&req).unwrap().k, 2);
    }

    #[test]
    fn different_tasks_get_separate_decisions() {
        let mut c = Coordinator::new(
            ExperimentConfig::default(),
            SplitPolicy::Online(OnlineOptimizer::default()),
        );
        c.submit(job(1, 120)).unwrap();
        c.submit(InferenceJob {
            id: 2,
            video: Video::with_frames("j", 120, 24.0),
            task: TaskProfile::simple_cnn(),
        })
        .unwrap();
        assert_eq!(c.decisions().len(), 2);
    }

    #[test]
    fn submit_keeps_the_uncapped_fixed_k_fast_path() {
        // The retired `decide_k` wrapper returned a fixed policy's k
        // uncapped, leaving run-time memory checks to reject
        // overcommitted runs — submit() preserves that quirk: a k=9 TX2
        // job launches 9 containers and fails in the container layer,
        // not in the planner.
        let mut over = Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(9));
        let err = over.submit(job(2, 720)).unwrap_err();
        assert!(format!("{err:#}").contains("exceed"), "{err:#}");
        // The plan surface, by contrast, caps to the memory grant.
        let j = job(3, 720);
        let req = over.request_for(&j);
        assert!(over.plan(&req).unwrap().k <= 6);
    }
}
