//! Typed experiment configuration: JSON file + CLI overrides + presets.
//!
//! Everything a run needs is in one `ExperimentConfig`, so benches,
//! examples and the CLI all construct runs the same way.

use crate::device::DeviceSpec;
use crate::util::cli::Parsed;
use crate::util::json::Json;
use crate::workload::{TaskProfile, Video};

/// Execution mode for the parallel executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Discrete-event simulation on the calibrated device model
    /// (regenerates the paper's figures).
    Sim,
    /// Real PJRT inference on throttled worker threads (wall-clock is
    /// measured; power is modeled from utilization).
    Real,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Some(ExecMode::Sim),
            "real" => Some(ExecMode::Real),
            _ => None,
        }
    }
}

/// Full description of one experiment run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub device: DeviceSpec,
    pub task: TaskProfile,
    pub video: Video,
    /// Number of containers (the paper's `x`).
    pub containers: usize,
    pub mode: ExecMode,
    /// Power-sensor sampling period (paper: 10 ms).
    pub sensor_period_s: f64,
    /// Startup cost override (None = device default).
    pub startup_s: Option<f64>,
    /// RNG seed for synthetic data.
    pub seed: u64,
    /// Artifacts directory for REAL mode.
    pub artifacts_dir: String,
    /// Model variant for REAL mode (e.g. "yolo_tiny_b4").
    pub variant: String,
    /// REAL mode: run the deterministic stub engine instead of PJRT —
    /// the full worker/throttle/metering path with no artifacts needed
    /// (CI smoke, hosts without `make artifacts`).
    pub stub_engine: bool,
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("unknown device {0:?} (expected tx2|orin)")]
    UnknownDevice(String),
    #[error("unknown task {0:?} (expected yolo_tiny|simple_cnn)")]
    UnknownTask(String),
    #[error("unknown mode {0:?} (expected sim|real)")]
    UnknownMode(String),
    #[error("bad config field {field}: {msg}")]
    BadField { field: &'static str, msg: String },
    #[error("config io: {0}")]
    Io(#[from] std::io::Error),
    #[error("config json: {0}")]
    Json(#[from] crate::util::json::JsonError),
}

impl Default for ExperimentConfig {
    /// The paper's base experiment: TX2, YOLO, 30-s video, benchmark
    /// single container, SIM mode.
    fn default() -> Self {
        ExperimentConfig {
            device: DeviceSpec::tx2(),
            task: TaskProfile::yolo_tiny(),
            video: Video::paper_default(),
            containers: 1,
            mode: ExecMode::Sim,
            sensor_period_s: 0.010,
            startup_s: None,
            seed: 0,
            artifacts_dir: "artifacts".to_string(),
            variant: "yolo_tiny_b4".to_string(),
            stub_engine: false,
        }
    }
}

fn task_by_name(name: &str) -> Option<TaskProfile> {
    match name.to_ascii_lowercase().as_str() {
        "yolo" | "yolo_tiny" => Some(TaskProfile::yolo_tiny()),
        "cnn" | "simple_cnn" => Some(TaskProfile::simple_cnn()),
        _ => None,
    }
}

impl ExperimentConfig {
    /// Resolve the effective device spec (startup override applied).
    pub fn effective_device(&self) -> DeviceSpec {
        let mut dev = self.device.clone();
        if let Some(s) = self.startup_s {
            dev.container_startup_s = s;
        }
        dev
    }

    /// Load from a JSON object (all fields optional; defaults fill in).
    pub fn from_json(v: &Json) -> Result<Self, ConfigError> {
        let mut cfg = ExperimentConfig::default();
        if let Some(d) = v.get("device").and_then(Json::as_str) {
            cfg.device = DeviceSpec::by_name(d)
                .ok_or_else(|| ConfigError::UnknownDevice(d.to_string()))?;
        }
        if let Some(t) = v.get("task").and_then(Json::as_str) {
            cfg.task =
                task_by_name(t).ok_or_else(|| ConfigError::UnknownTask(t.to_string()))?;
        }
        if let Some(m) = v.get("mode").and_then(Json::as_str) {
            cfg.mode =
                ExecMode::parse(m).ok_or_else(|| ConfigError::UnknownMode(m.to_string()))?;
        }
        if let Some(f) = v.get("frames").and_then(Json::as_usize) {
            cfg.video = Video::with_frames("config", f, cfg.video.fps);
        }
        if let Some(k) = v.get("containers").and_then(Json::as_usize) {
            if k == 0 {
                return Err(ConfigError::BadField {
                    field: "containers",
                    msg: "must be >= 1".into(),
                });
            }
            cfg.containers = k;
        }
        if let Some(p) = v.get("sensor_period_s").and_then(Json::as_f64) {
            if p <= 0.0 {
                return Err(ConfigError::BadField {
                    field: "sensor_period_s",
                    msg: "must be positive".into(),
                });
            }
            cfg.sensor_period_s = p;
        }
        if let Some(s) = v.get("startup_s").and_then(Json::as_f64) {
            cfg.startup_s = Some(s);
        }
        if let Some(s) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = s as u64;
        }
        if let Some(d) = v.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = d.to_string();
        }
        if let Some(d) = v.get("variant").and_then(Json::as_str) {
            cfg.variant = d.to_string();
        }
        if let Some(b) = v.get("stub_engine").and_then(Json::as_bool) {
            cfg.stub_engine = b;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Apply CLI overrides (highest precedence).
    pub fn apply_cli(&mut self, p: &Parsed) -> Result<(), ConfigError> {
        if let Some(d) = p.get("device") {
            self.device = DeviceSpec::by_name(d)
                .ok_or_else(|| ConfigError::UnknownDevice(d.to_string()))?;
        }
        if let Some(t) = p.get("task") {
            self.task =
                task_by_name(t).ok_or_else(|| ConfigError::UnknownTask(t.to_string()))?;
        }
        if let Some(m) = p.get("mode") {
            self.mode =
                ExecMode::parse(m).ok_or_else(|| ConfigError::UnknownMode(m.to_string()))?;
        }
        if let Some(k) = p.get("containers") {
            let k: usize = k.parse().map_err(|_| ConfigError::BadField {
                field: "containers",
                msg: format!("not an integer: {k:?}"),
            })?;
            if k == 0 {
                return Err(ConfigError::BadField {
                    field: "containers",
                    msg: "must be >= 1".into(),
                });
            }
            self.containers = k;
        }
        if let Some(f) = p.get("frames") {
            let f: usize = f.parse().map_err(|_| ConfigError::BadField {
                field: "frames",
                msg: format!("not an integer: {f:?}"),
            })?;
            self.video = Video::with_frames("cli", f, self.video.fps);
        }
        if let Some(a) = p.get("artifacts") {
            self.artifacts_dir = a.to_string();
        }
        if let Some(v) = p.get("variant") {
            self.variant = v.to_string();
        }
        if p.flag("stub-engine") {
            self.stub_engine = true;
        }
        Ok(())
    }

    /// Serialize (for provenance records next to experiment outputs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("device", Json::str(self.device.name)),
            ("task", Json::str(&self.task.name)),
            ("frames", Json::num(self.video.frame_count() as f64)),
            ("containers", Json::num(self.containers as f64)),
            (
                "mode",
                Json::str(match self.mode {
                    ExecMode::Sim => "sim",
                    ExecMode::Real => "real",
                }),
            ),
            ("sensor_period_s", Json::num(self.sensor_period_s)),
            ("seed", Json::num(self.seed as f64)),
            ("variant", Json::str(&self.variant)),
            ("stub_engine", Json::Bool(self.stub_engine)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::{Command, OptSpec};

    #[test]
    fn default_is_paper_benchmark() {
        let c = ExperimentConfig::default();
        assert_eq!(c.device.name, "jetson-tx2");
        assert_eq!(c.containers, 1);
        assert_eq!(c.video.frame_count(), 720);
        assert_eq!(c.mode, ExecMode::Sim);
    }

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{"device": "orin", "task": "simple_cnn", "containers": 4,
                "frames": 100, "mode": "real", "seed": 9}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.device.name, "jetson-agx-orin");
        assert_eq!(c.task.name, "simple_cnn");
        assert_eq!(c.containers, 4);
        assert_eq!(c.video.frame_count(), 100);
        assert_eq!(c.mode, ExecMode::Real);
        assert_eq!(c.seed, 9);
    }

    #[test]
    fn from_json_rejects_bad_values() {
        for (src, what) in [
            (r#"{"device": "nano"}"#, "device"),
            (r#"{"task": "resnet"}"#, "task"),
            (r#"{"mode": "hybrid"}"#, "mode"),
            (r#"{"containers": 0}"#, "containers"),
            (r#"{"sensor_period_s": -1}"#, "period"),
        ] {
            assert!(
                ExperimentConfig::from_json(&Json::parse(src).unwrap()).is_err(),
                "{what} should fail"
            );
        }
    }

    #[test]
    fn cli_overrides_config() {
        let cmd = Command::new("t", "t")
            .opt(OptSpec::opt("device", ""))
            .opt(OptSpec::opt("containers", ""));
        let parsed = cmd.parse(["--device", "orin", "--containers", "6"]).unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_cli(&parsed).unwrap();
        assert_eq!(c.device.name, "jetson-agx-orin");
        assert_eq!(c.containers, 6);
    }

    #[test]
    fn to_json_roundtrip() {
        let c = ExperimentConfig::default();
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.device.name, c.device.name);
        assert_eq!(c2.containers, c.containers);
        assert_eq!(c2.video.frame_count(), c.video.frame_count());
    }

    #[test]
    fn startup_override() {
        let j = Json::parse(r#"{"startup_s": 2.5}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.effective_device().container_startup_s, 2.5);
        assert_eq!(ExperimentConfig::default().effective_device().container_startup_s, 0.0);
    }
}
