//! Model fitting for Table II: quadratic (TX2) and exponential-decay
//! (AGX Orin) convex models of normalized time / energy / power as a
//! function of the container count.

pub mod crossval;
pub mod expfit;
pub mod eval;
pub mod polyfit;

pub use crossval::select_by_cv;
pub use expfit::{fit_exponential, ExpModel};
pub use eval::{convexity_ok, r2_of_fit};
pub use polyfit::{fit_quadratic, PolyModel};

/// Which functional family Table II uses for a device.
#[derive(Debug, Clone, PartialEq)]
pub enum FittedModel {
    /// `a*x^2 + b*x + c` (TX2 rows).
    Quadratic(PolyModel),
    /// `a + b*exp(c*x)` (Orin rows).
    Exponential(ExpModel),
}

impl FittedModel {
    pub fn eval(&self, x: f64) -> f64 {
        match self {
            FittedModel::Quadratic(m) => m.eval(x),
            FittedModel::Exponential(m) => m.eval(x),
        }
    }

    /// Container count minimizing the model on `[1, k_max]` (the paper's
    /// future-work online scheduler uses this).
    pub fn argmin(&self, k_max: usize) -> usize {
        (1..=k_max)
            .min_by(|&a, &b| {
                self.eval(a as f64)
                    .partial_cmp(&self.eval(b as f64))
                    .unwrap()
            })
            .unwrap_or(1)
    }

    pub fn describe(&self) -> String {
        match self {
            FittedModel::Quadratic(m) => {
                format!("{:.4}x^2 + {:+.4}x + {:+.4}", m.a2, m.a1, m.a0)
            }
            FittedModel::Exponential(m) => {
                format!("{:.4} + {:.4}*exp({:.4}x)", m.a, m.b, m.c)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_of_quadratic() {
        // paper TX2 time model: 0.026x^2 - 0.21x + 1.17, vertex ~4.04
        let m = FittedModel::Quadratic(PolyModel { a2: 0.026, a1: -0.21, a0: 1.17 });
        assert_eq!(m.argmin(6), 4);
    }

    #[test]
    fn argmin_of_exponential_decay() {
        // paper Orin time model: 0.33 + 1.77 e^{-0.98x} — monotone down
        let m = FittedModel::Exponential(ExpModel { a: 0.33, b: 1.77, c: -0.98 });
        assert_eq!(m.argmin(12), 12);
    }

    #[test]
    fn describe_contains_coefficients() {
        let q = FittedModel::Quadratic(PolyModel { a2: 0.026, a1: -0.21, a0: 1.17 });
        assert!(q.describe().contains("0.026"));
        let e = FittedModel::Exponential(ExpModel { a: 0.33, b: 1.77, c: -0.98 });
        assert!(e.describe().contains("exp"));
    }
}
