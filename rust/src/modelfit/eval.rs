//! Fit-quality evaluation: R² against observations and discrete
//! convexity checks (the paper emphasizes its fitted models are convex,
//! which is what makes the online optimal-k search well-behaved).

use super::FittedModel;
use crate::util::stats::r_squared;

/// R² of a fitted model over observation pairs.
pub fn r2_of_fit(model: &FittedModel, xs: &[f64], ys: &[f64]) -> f64 {
    let pred: Vec<f64> = xs.iter().map(|&x| model.eval(x)).collect();
    r_squared(&pred, ys)
}

/// Discrete convexity of a sampled curve: second differences >= -tol.
pub fn convexity_ok(ys: &[f64], tol: f64) -> bool {
    ys.windows(3).all(|w| w[2] - 2.0 * w[1] + w[0] >= -tol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelfit::{ExpModel, PolyModel};

    #[test]
    fn r2_perfect_fit() {
        let m = FittedModel::Quadratic(PolyModel { a2: 1.0, a1: 0.0, a0: 0.0 });
        let xs = [1.0, 2.0, 3.0];
        let ys = [1.0, 4.0, 9.0];
        assert!((r2_of_fit(&m, &xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_bad_fit_is_low() {
        let m = FittedModel::Exponential(ExpModel { a: 100.0, b: 0.0, c: 0.0 });
        let xs = [1.0, 2.0, 3.0];
        let ys = [1.0, 2.0, 3.0];
        assert!(r2_of_fit(&m, &xs, &ys) < 0.0);
    }

    #[test]
    fn convexity_detection() {
        assert!(convexity_ok(&[4.0, 1.0, 0.0, 1.0, 4.0], 1e-9)); // x^2 samples
        assert!(!convexity_ok(&[0.0, 1.0, 0.0], 1e-9)); // concave bump
        assert!(convexity_ok(&[1.0, 1.0], 1e-9)); // too short: trivially ok
        // decaying exponential is convex
        let ys: Vec<f64> = (1..=12).map(|k| 0.33 + 1.77 * (-0.98 * k as f64).exp()).collect();
        assert!(convexity_ok(&ys, 1e-9));
    }
}
