//! Exponential model fit `y = a + b*exp(c*x)` (Table II, Orin rows)
//! via Gauss–Newton with a line search, seeded by a log-linear
//! initialization.

use crate::util::stats::{least_squares, solve_linear};

/// `a + b * exp(c * x)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpModel {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl ExpModel {
    pub fn eval(&self, x: f64) -> f64 {
        self.a + self.b * (self.c * x).exp()
    }

    /// Convex iff b >= 0 (second derivative `b*c^2*e^{cx}`).
    pub fn is_convex(&self) -> bool {
        self.b >= 0.0
    }

    /// Asymptote as x -> inf for decaying models (c < 0).
    pub fn asymptote(&self) -> f64 {
        self.a
    }
}

fn sse(m: &ExpModel, xs: &[f64], ys: &[f64]) -> f64 {
    xs.iter().zip(ys).map(|(&x, &y)| (m.eval(x) - y).powi(2)).sum()
}

/// Initial guess: assume a ~ min(y) - small margin (decay) or max(y)
/// (growth), then log-linear regression of `|y - a|`.
fn init_guess(xs: &[f64], ys: &[f64]) -> ExpModel {
    let decaying = ys.first() > ys.last();
    let (lo, hi) = ys.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &y| {
        (lo.min(y), hi.max(y))
    });
    let span = (hi - lo).max(1e-9);
    let a = if decaying { lo - 0.05 * span } else { hi + 0.05 * span };
    // log(|y - a|) = log|b| + c x
    let mut design = Vec::with_capacity(xs.len() * 2);
    let mut targets = Vec::with_capacity(xs.len());
    for (&x, &y) in xs.iter().zip(ys) {
        let d = (y - a).abs().max(1e-12);
        design.extend_from_slice(&[1.0, x]);
        targets.push(d.ln());
    }
    match least_squares(&design, &targets, xs.len(), 2) {
        Some(beta) => {
            let b_mag = beta[0].exp();
            let sign = if ys[0] >= a { 1.0 } else { -1.0 };
            ExpModel { a, b: sign * b_mag, c: beta[1] }
        }
        None => ExpModel { a, b: span, c: -1.0 },
    }
}

/// Gauss–Newton with backtracking; returns `None` if it cannot improve
/// on the initialization at all (degenerate data).
pub fn fit_exponential(xs: &[f64], ys: &[f64]) -> Option<ExpModel> {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 3 {
        return None;
    }
    let mut m = init_guess(xs, ys);
    let mut err = sse(&m, xs, ys);
    for _ in 0..200 {
        // Jacobian: d/da = 1, d/db = e^{cx}, d/dc = b x e^{cx}
        let n = xs.len();
        let mut jtj = vec![0.0; 9];
        let mut jtr = vec![0.0; 3];
        for i in 0..n {
            let e = (m.c * xs[i]).exp();
            let row = [1.0, e, m.b * xs[i] * e];
            let resid = ys[i] - m.eval(xs[i]);
            for a in 0..3 {
                jtr[a] += row[a] * resid;
                for b in 0..3 {
                    jtj[a * 3 + b] += row[a] * row[b];
                }
            }
        }
        // Levenberg damping for stability
        for d in 0..3 {
            jtj[d * 3 + d] *= 1.0 + 1e-8;
        }
        let step = solve_linear(&mut jtj, &mut jtr, 3)?;
        // backtracking line search
        let mut t = 1.0;
        let mut improved = false;
        for _ in 0..30 {
            let cand = ExpModel {
                a: m.a + t * step[0],
                b: m.b + t * step[1],
                c: m.c + t * step[2],
            };
            let cand_err = sse(&cand, xs, ys);
            if cand_err < err && cand_err.is_finite() {
                m = cand;
                err = cand_err;
                improved = true;
                break;
            }
            t *= 0.5;
        }
        if !improved {
            break;
        }
        if err < 1e-18 {
            break;
        }
    }
    if err.is_finite() {
        Some(m)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::close;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_paper_orin_time_model() {
        // Table II: 0.33 + 1.77 e^{-0.98x}
        let truth = ExpModel { a: 0.33, b: 1.77, c: -0.98 };
        let xs: Vec<f64> = (1..=12).map(|k| k as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| truth.eval(x)).collect();
        let fit = fit_exponential(&xs, &ys).unwrap();
        assert!(close(fit.a, truth.a, 1e-4).is_ok(), "a={}", fit.a);
        assert!(close(fit.b, truth.b, 1e-3).is_ok(), "b={}", fit.b);
        assert!(close(fit.c, truth.c, 1e-3).is_ok(), "c={}", fit.c);
        assert!(fit.is_convex());
    }

    #[test]
    fn recovers_growth_model() {
        // Orin power row grows: 1.85 - 1.24 e^{-0.38x} (negative b).
        let truth = ExpModel { a: 1.85, b: -1.24, c: -0.38 };
        let xs: Vec<f64> = (1..=12).map(|k| k as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| truth.eval(x)).collect();
        let fit = fit_exponential(&xs, &ys).unwrap();
        assert!(close(fit.a, truth.a, 1e-2).is_ok(), "a={}", fit.a);
        assert!(close(fit.b, truth.b, 1e-2).is_ok(), "b={}", fit.b);
        assert!(close(fit.c, truth.c, 1e-2).is_ok(), "c={}", fit.c);
        assert!(!fit.is_convex());
    }

    #[test]
    fn noisy_recovery() {
        let truth = ExpModel { a: 0.59, b: 1.14, c: -1.03 }; // Orin energy
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (1..=12).map(|k| k as f64).collect();
        let ys: Vec<f64> =
            xs.iter().map(|&x| truth.eval(x) + rng.normal_ms(0.0, 0.005)).collect();
        let fit = fit_exponential(&xs, &ys).unwrap();
        assert!((fit.a - truth.a).abs() < 0.05);
        assert!((fit.c - truth.c).abs() < 0.25);
    }

    #[test]
    fn too_few_points() {
        assert!(fit_exponential(&[1.0, 2.0], &[1.0, 0.5]).is_none());
    }

    #[test]
    fn asymptote_matches_a() {
        let m = ExpModel { a: 0.33, b: 1.77, c: -0.98 };
        assert_eq!(m.asymptote(), 0.33);
        assert!((m.eval(50.0) - 0.33).abs() < 1e-12);
    }
}
