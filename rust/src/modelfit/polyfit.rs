//! Quadratic least-squares fit (Table II, TX2 rows).

use crate::util::stats::least_squares;

/// `a2*x^2 + a1*x + a0`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolyModel {
    pub a2: f64,
    pub a1: f64,
    pub a0: f64,
}

impl PolyModel {
    pub fn eval(&self, x: f64) -> f64 {
        self.a2 * x * x + self.a1 * x + self.a0
    }

    /// Convex iff the leading coefficient is non-negative.
    pub fn is_convex(&self) -> bool {
        self.a2 >= 0.0
    }

    /// Continuous vertex location (minimum if convex).
    pub fn vertex(&self) -> Option<f64> {
        if self.a2.abs() < 1e-15 {
            None
        } else {
            Some(-self.a1 / (2.0 * self.a2))
        }
    }
}

/// OLS quadratic through `(x, y)` points. Needs >= 3 distinct x.
pub fn fit_quadratic(xs: &[f64], ys: &[f64]) -> Option<PolyModel> {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 3 {
        return None;
    }
    let mut design = Vec::with_capacity(xs.len() * 3);
    for &x in xs {
        design.extend_from_slice(&[1.0, x, x * x]);
    }
    let beta = least_squares(&design, ys, xs.len(), 3)?;
    Some(PolyModel { a0: beta[0], a1: beta[1], a2: beta[2] })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{close, forall};
    use crate::util::rng::Rng;

    #[test]
    fn recovers_exact_quadratic() {
        let xs: Vec<f64> = (1..=6).map(|k| k as f64).collect();
        let truth = PolyModel { a2: 0.026, a1: -0.21, a0: 1.17 }; // paper TX2 time
        let ys: Vec<f64> = xs.iter().map(|&x| truth.eval(x)).collect();
        let fit = fit_quadratic(&xs, &ys).unwrap();
        assert!(close(fit.a2, truth.a2, 1e-9).is_ok());
        assert!(close(fit.a1, truth.a1, 1e-9).is_ok());
        assert!(close(fit.a0, truth.a0, 1e-9).is_ok());
        assert!(fit.is_convex());
        assert!(close(fit.vertex().unwrap(), 4.038, 0.01).is_ok());
    }

    #[test]
    fn too_few_points() {
        assert!(fit_quadratic(&[1.0, 2.0], &[1.0, 2.0]).is_none());
    }

    #[test]
    fn degenerate_same_x_is_singular() {
        assert!(fit_quadratic(&[2.0, 2.0, 2.0], &[1.0, 2.0, 3.0]).is_none());
    }

    #[test]
    fn noisy_fit_recovers_approximately() {
        let mut rng = Rng::new(77);
        let truth = PolyModel { a2: 0.015, a1: -0.12, a0: 1.10 }; // TX2 energy
        let xs: Vec<f64> = (1..=24).map(|k| k as f64 * 0.25).collect();
        let ys: Vec<f64> =
            xs.iter().map(|&x| truth.eval(x) + rng.normal_ms(0.0, 0.002)).collect();
        let fit = fit_quadratic(&xs, &ys).unwrap();
        assert!((fit.a2 - truth.a2).abs() < 0.005);
        assert!((fit.a1 - truth.a1).abs() < 0.02);
    }

    #[test]
    fn linear_data_gives_near_zero_a2() {
        forall(
            3,
            30,
            |r| (r.range_f64(-2.0, 2.0), r.range_f64(-1.0, 1.0)),
            |&(slope, icept)| {
                let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
                let ys: Vec<f64> = xs.iter().map(|&x| slope * x + icept).collect();
                let fit = fit_quadratic(&xs, &ys).unwrap();
                close(fit.a2, 0.0, 1e-8)?;
                close(fit.a1, slope, 1e-7)
            },
        );
    }
}
