//! Leave-one-out cross-validated model selection (extension).
//!
//! Table II uses a quadratic on TX2 and an exponential on Orin; the
//! paper doesn't say how the family was chosen. In-sample R² favors
//! whichever family has more effective flexibility around the sampled
//! range; LOO-CV is the honest criterion and is what the online
//! optimizer should trust when probes are few.

use super::{fit_exponential, fit_quadratic, FittedModel};

/// LOO-CV mean squared prediction error of a family on (xs, ys).
/// `fit` returns None when a fold is unfittable; such folds count as
/// failures and poison the family (returns None).
fn loo_mse<F>(xs: &[f64], ys: &[f64], fit: F) -> Option<f64>
where
    F: Fn(&[f64], &[f64]) -> Option<FittedModel>,
{
    let n = xs.len();
    if n < 5 {
        return None; // folds would be too small for 3-parameter fits
    }
    let mut sse = 0.0;
    for hold in 0..n {
        let train_x: Vec<f64> =
            xs.iter().enumerate().filter(|(i, _)| *i != hold).map(|(_, v)| *v).collect();
        let train_y: Vec<f64> =
            ys.iter().enumerate().filter(|(i, _)| *i != hold).map(|(_, v)| *v).collect();
        let model = fit(&train_x, &train_y)?;
        sse += (model.eval(xs[hold]) - ys[hold]).powi(2);
    }
    Some(sse / n as f64)
}

/// Pick the family with the lower LOO-CV error; returns the model
/// refitted on ALL data plus both families' CV errors.
pub fn select_by_cv(
    xs: &[f64],
    ys: &[f64],
) -> Option<(FittedModel, &'static str, f64, f64)> {
    let quad_cv = loo_mse(xs, ys, |x, y| fit_quadratic(x, y).map(FittedModel::Quadratic));
    let exp_cv = loo_mse(xs, ys, |x, y| fit_exponential(x, y).map(FittedModel::Exponential));
    match (quad_cv, exp_cv) {
        (Some(q), Some(e)) => {
            if e < q {
                let m = FittedModel::Exponential(fit_exponential(xs, ys)?);
                Some((m, "exponential", q, e))
            } else {
                let m = FittedModel::Quadratic(fit_quadratic(xs, ys)?);
                Some((m, "quadratic", q, e))
            }
        }
        (Some(q), None) => {
            Some((FittedModel::Quadratic(fit_quadratic(xs, ys)?), "quadratic", q, f64::INFINITY))
        }
        (None, Some(e)) => Some((
            FittedModel::Exponential(fit_exponential(xs, ys)?),
            "exponential",
            f64::INFINITY,
            e,
        )),
        (None, None) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exponential_data_selects_exponential() {
        let mut rng = Rng::new(1);
        let xs: Vec<f64> = (1..=12).map(|k| k as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| 0.33 + 1.77 * (-0.98 * x).exp() + rng.normal_ms(0.0, 0.002))
            .collect();
        let (_, family, q, e) = select_by_cv(&xs, &ys).unwrap();
        assert_eq!(family, "exponential", "cv quad={q:.2e} exp={e:.2e}");
    }

    #[test]
    fn quadratic_data_selects_quadratic() {
        let mut rng = Rng::new(2);
        // full TX2 range including the k>4 up-turn — exactly where a
        // quadratic beats a monotone exponential decay
        let xs: Vec<f64> = (1..=6).map(|k| k as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&x| 0.026 * x * x - 0.21 * x + 1.17 + rng.normal_ms(0.0, 0.002))
            .collect();
        let (_, family, q, e) = select_by_cv(&xs, &ys).unwrap();
        assert_eq!(family, "quadratic", "cv quad={q:.2e} exp={e:.2e}");
    }

    #[test]
    fn paper_device_split_recovered_from_simulated_sweeps() {
        // Run the actual simulator sweeps and confirm CV picks the
        // paper's family per device: quadratic (TX2), exponential (Orin).
        use crate::config::ExperimentConfig;
        use crate::coordinator::executor::run_sim;
        use crate::device::DeviceSpec;
        for (device, want) in
            [(DeviceSpec::tx2(), "quadratic"), (DeviceSpec::orin(), "exponential")]
        {
            let k_max = device.memory.max_containers(720);
            let mut cfg = ExperimentConfig::default();
            cfg.device = device.clone();
            cfg.containers = 1;
            let bench = run_sim(&cfg).unwrap();
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for k in 1..=k_max {
                let mut c = cfg.clone();
                c.containers = k;
                xs.push(k as f64);
                ys.push(run_sim(&c).unwrap().time_s / bench.time_s);
            }
            let (_, family, ..) = select_by_cv(&xs, &ys).unwrap();
            assert_eq!(family, want, "{}", device.name);
        }
    }

    #[test]
    fn too_few_points_returns_none() {
        assert!(select_by_cv(&[1.0, 2.0, 3.0], &[1.0, 0.8, 0.7]).is_none());
    }
}
