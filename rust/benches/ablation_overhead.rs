//! A1 — ablation: container startup overhead.
//!
//! The paper meters steady-state inference (containers pre-started). If
//! startup cost were charged to the run, high k would pay k parallel
//! startups plus per-container model loads — this ablation quantifies
//! when that erodes the splitting gain, which matters for the online
//! scheduler's break-even on SHORT videos.

use divide_and_save::bench::{banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;

fn main() {
    banner("A1", "startup-overhead ablation (TX2, k sweep)");
    let startups = [0.0, 1.0, 2.5, 5.0];
    let frame_counts = [72usize, 720];

    for frames in frame_counts {
        println!("\n-- {frames} frames --");
        let mut table = Table::new(["k", "s=0.0", "s=1.0", "s=2.5", "s=5.0"]);
        let mut best_k_by_startup = Vec::new();
        for &s in &startups {
            let mut best = (1usize, f64::INFINITY);
            for k in 1..=6 {
                let mut cfg = ExperimentConfig::default();
                cfg.video = divide_and_save::workload::Video::with_frames("a", frames, 24.0);
                cfg.containers = k;
                cfg.startup_s = Some(s);
                let e = run_sim(&cfg).unwrap().energy_j;
                if e < best.1 {
                    best = (k, e);
                }
            }
            best_k_by_startup.push(best.0);
        }
        for k in 1..=6usize {
            let mut row = vec![k.to_string()];
            for &s in &startups {
                let mut cfg = ExperimentConfig::default();
                cfg.video = divide_and_save::workload::Video::with_frames("a", frames, 24.0);
                cfg.containers = k;
                cfg.startup_s = Some(s);
                let r = run_sim(&cfg).unwrap();
                row.push(format!("{:.0}J/{:.0}s", r.energy_j, r.time_s));
            }
            table.row(row);
        }
        table.print();
        println!("energy-optimal k per startup cost {startups:?}: {best_k_by_startup:?}");
        if frames == 720 {
            // long video: startup is amortized, splitting still wins
            assert!(
                best_k_by_startup.iter().all(|&k| k >= 3),
                "720 frames: splitting should stay optimal under startup cost"
            );
        }
    }
    println!("\ntakeaway: startup cost shifts the optimal k down only for short videos —");
    println!("the paper's steady-state assumption is safe for its 30-s workload.");
}
