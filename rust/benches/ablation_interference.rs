//! A2 — ablation: the scheduler-interference model.
//!
//! The paper attributes TX2's degradation past k=4 to the CPU scheduler
//! struggling when containers outnumber cores. Our model carries that
//! as `I(k) = 1 + alpha*max(0, k-C)/C`. Sweeping alpha shows alpha=0
//! ERASES the observed degradation (k=6 would tie k=4) while the
//! calibrated alpha reproduces it — evidence the term is load-bearing,
//! plus a first-principles cross-check from context-switch costs.

use divide_and_save::bench::{banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::sched::interference;

fn main() {
    banner("A2", "interference-model ablation (TX2)");
    let alphas = [0.0, 0.2, 0.4, 0.8];
    let mut table = Table::new(["k", "a=0.0", "a=0.2", "a=0.4 (calibrated)", "a=0.8"]);
    let t_ratio = |alpha: f64, k: usize| -> f64 {
        let mut cfg = ExperimentConfig::default();
        cfg.device.interference_alpha = alpha;
        cfg.containers = 1;
        let bench = run_sim(&cfg).unwrap();
        cfg.containers = k;
        run_sim(&cfg).unwrap().time_s / bench.time_s
    };
    let mut grid = Vec::new();
    for k in 1..=6usize {
        let mut row = vec![k.to_string()];
        let mut vals = Vec::new();
        for &a in &alphas {
            let v = t_ratio(a, k);
            vals.push(v);
            row.push(format!("{v:.3}"));
        }
        grid.push(vals);
        table.row(row);
    }
    table.print();

    // alpha = 0: k=6 ties k=4 (CFS sharing is lossless in the model)
    assert!(
        (grid[5][0] - grid[3][0]).abs() < 0.005,
        "without interference, k=6 must tie k=4"
    );
    // calibrated alpha: k=6 strictly worse than k=4, as the paper observed
    assert!(
        grid[5][2] > grid[3][2] + 0.05,
        "calibrated alpha must reproduce the TX2 degradation"
    );
    println!("\nalpha=0 erases the paper's k>4 degradation; alpha=0.4 reproduces it ✓");

    // First-principles cross-check: per-frame time inflation implied by
    // involuntary context switches.
    let mut cs = Table::new(["k", "ctx-switch overhead", "model I(k)-1"]);
    for k in 4..=8usize {
        let o = interference::context_switch_overhead(k, 4.0, 2000.0, 50e-6);
        let i = interference::penalty(k, 4.0, 0.4) - 1.0;
        cs.row([k.to_string(), format!("{:.3}", o), format!("{i:.3}")]);
    }
    cs.print();
    println!("(2000 switches/s x 50us at k-C oversubscription lands within ~2x of the");
    println!(" calibrated alpha — the fitted constant is physically plausible.)");
}
