//! A7 — ablation: fixed vs elastic core grants on the serving engine.
//!
//! The elastic policy re-apportions a device's cores across all
//! resident jobs at every admission/completion event (work-conserving
//! regrants); the fixed policy freezes each job's grant at admission
//! (PR 1 semantics). Three claims, asserted at runtime:
//!
//! (a) **Paper parity.** With a single job on an idle device there is
//!     no event to regrant on, so elastic and fixed produce identical
//!     time and energy — the paper's single-video numbers survive the
//!     policy change untouched.
//! (b) **Strictly better under bursty overload.** At the A5 serving
//!     bench's bursty-MMPP operating point (whose bursts overrun the
//!     server) with a realistic mix of short and long clips, elastic
//!     grants give strictly lower mean latency AND strictly lower total
//!     energy: when a burst's short jobs drain, the fixed policy leaves
//!     the survivor crawling on its admission share while most of the
//!     device idles — exactly the idle energy the paper set out to
//!     eliminate.
//! (c) **Work conservation.** The engine's self-audit (no ungranted
//!     core while work is resident, checked after every dispatch)
//!     records zero violations across the elastic runs; the tier-1
//!     property test `elastic_grants_are_work_conserving` covers the
//!     randomized version.

use divide_and_save::bench::{a5_bursty_mixed_jobs, banner, Table};
use divide_and_save::device::DeviceSpec;
use divide_and_save::server::{
    EngineConfig, EngineJob, EngineOutcome, GrantPolicy, ServingEngine, SplitDecider,
};
use divide_and_save::util::stats::summarize;
use divide_and_save::workload::TaskProfile;

fn run_single(device: DeviceSpec, grant_policy: GrantPolicy) -> EngineOutcome {
    let mut cfg = EngineConfig::single_node(device);
    cfg.max_concurrent_jobs = 3;
    cfg.grant_policy = grant_policy;
    let jobs = vec![EngineJob::new(0, 0.0, 720, TaskProfile::yolo_tiny())];
    ServingEngine::new(cfg, jobs, SplitDecider::PerNodeOptimal).run().unwrap()
}

fn run_overload(grant_policy: GrantPolicy) -> EngineOutcome {
    let mut cfg = EngineConfig::single_node(DeviceSpec::orin());
    cfg.max_concurrent_jobs = 3;
    cfg.grant_policy = grant_policy;
    // A5's k=4 row: the paper's fixed split, availability-capped, over
    // the shared A5 bursty mixed-clip trace (`bench::a5_bursty_mixed_jobs`).
    ServingEngine::new(cfg, a5_bursty_mixed_jobs(80), SplitDecider::Fixed(4)).run().unwrap()
}

fn main() {
    banner("A7", "fixed vs elastic grants (paper parity + bursty overload)");

    // ---- (a) single job, idle device: elastic degenerates to fixed ---
    let mut parity = Table::new(["device", "grants", "time_s", "energy_j"]);
    for device in [DeviceSpec::tx2(), DeviceSpec::orin()] {
        let fixed = run_single(device.clone(), GrantPolicy::Fixed);
        let elastic = run_single(device.clone(), GrantPolicy::Elastic);
        for (name, out) in [("fixed", &fixed), ("elastic", &elastic)] {
            parity.row([
                device.name.to_string(),
                name.to_string(),
                format!("{:.1}", out.wall_s),
                format!("{:.1}", out.node_energy_j[0]),
            ]);
        }
        assert!(
            (fixed.wall_s - elastic.wall_s).abs() < 1e-9,
            "{}: single-job time diverged: fixed {} vs elastic {}",
            device.name,
            fixed.wall_s,
            elastic.wall_s
        );
        assert!(
            (fixed.node_energy_j[0] - elastic.node_energy_j[0]).abs() < 1e-9,
            "{}: single-job energy diverged",
            device.name
        );
        assert_eq!(elastic.regrants, 0, "a lone job must never be regranted");
    }
    parity.print();
    println!("\n(a) single job, idle device: elastic == fixed exactly — the paper's");
    println!("    validated single-video time/energy survive the policy change ✓");

    // ---- (b) A5's bursty overload, mixed clip lengths ----------------
    banner("A7b", "bursty MMPP overload (Orin, 3 slots, k=4, every 4th job long)");
    let fixed = run_overload(GrantPolicy::Fixed);
    let elastic = run_overload(GrantPolicy::Elastic);
    let mut table = Table::new([
        "grants", "mean_lat_s", "p95_lat_s", "energy_kj", "wall_s", "regrants",
    ]);
    let mut stats = Vec::new();
    for (name, out) in [("fixed", &fixed), ("elastic", &elastic)] {
        let latencies: Vec<f64> = out.completed.iter().map(|c| c.latency_s()).collect();
        let lat = summarize(&latencies);
        table.row([
            name.to_string(),
            format!("{:.2}", lat.mean),
            format!("{:.2}", lat.p95),
            format!("{:.2}", out.node_energy_j[0] / 1e3),
            format!("{:.0}", out.wall_s),
            format!("{}", out.regrants),
        ]);
        stats.push((name, lat.mean, out.node_energy_j[0]));
    }
    table.print();
    let (_, mean_fixed, energy_fixed) = stats[0];
    let (_, mean_elastic, energy_elastic) = stats[1];
    assert!(
        mean_elastic < mean_fixed,
        "elastic mean latency {mean_elastic:.2}s must be strictly below fixed {mean_fixed:.2}s"
    );
    assert!(
        energy_elastic < energy_fixed,
        "elastic energy {energy_elastic:.0}J must be strictly below fixed {energy_fixed:.0}J"
    );
    assert!(elastic.regrants > 0, "the bursty mix must trigger regrants");
    assert_eq!(fixed.regrants, 0);

    // ---- (c) work conservation held throughout -----------------------
    assert_eq!(
        elastic.metrics.counter("work_conservation_violations"),
        0,
        "elastic run left cores ungranted while work was resident"
    );

    println!("\n(b) at the A5 bursty overload point, elastic grants are strictly");
    println!("    better on BOTH mean latency and energy (survivors expand instead");
    println!("    of crawling on their admission share while the device idles) ✓");
    println!("(c) zero work-conservation violations across the elastic run ✓");
}
