//! E1 — paper Fig. 1: single container, sweep `--cpus` from 0.1 to the
//! device core count; report inference time and energy for the full
//! 720-frame video on both devices.
//!
//! Expected shape (paper): steep time/energy drop up to ~2 cores, then
//! strong diminishing returns — TX2's 4th core barely helps; Orin gains
//! little beyond 2 cores for a single container.

use divide_and_save::bench::{banner, Table};
use divide_and_save::device::{DeviceSpec, PowerSensor};
use divide_and_save::energy::meter_schedule;
use divide_and_save::sched::{CpuScheduler, JobSpec};
use divide_and_save::util::csv::CsvWriter;

fn cpu_grid(cores: f64) -> Vec<f64> {
    let mut g = vec![0.1, 0.25, 0.5, 0.75];
    let mut c = 1.0;
    while c <= cores + 1e-9 {
        g.push(c);
        c += 0.5;
    }
    g
}

fn main() {
    banner("E1 / Fig.1", "single container, cpus sweep, 720 frames");
    let sensor = PowerSensor::default();
    for device in DeviceSpec::all() {
        println!("\n-- {} --", device.name);
        let mut table = Table::new(["cpus", "time_s", "energy_j", "power_w"]);
        let mut csv = CsvWriter::new(["cpus", "time_s", "energy_j", "power_w"]);
        let mut prev_time = f64::INFINITY;
        let mut prev_energy = f64::INFINITY;
        for cpus in cpu_grid(device.cores) {
            let sched = CpuScheduler::new(&device);
            let schedule = sched.run(&[JobSpec {
                container_id: 0,
                frames: 720,
                cpus,
                ready_at_s: 0.0,
            }]);
            let rep = meter_schedule(&device, &sensor, &schedule);
            assert!(
                rep.time_s <= prev_time + 1e-9 && rep.energy_j <= prev_energy + 1e-6,
                "Fig.1 curves must be monotone non-increasing"
            );
            prev_time = rep.time_s;
            prev_energy = rep.energy_j;
            table.row([
                format!("{cpus:.2}"),
                format!("{:.1}", rep.time_s),
                format!("{:.1}", rep.energy_j),
                format!("{:.2}", rep.avg_power_w),
            ]);
            csv.row([
                cpus.to_string(),
                rep.time_s.to_string(),
                rep.energy_j.to_string(),
                rep.avg_power_w.to_string(),
            ]);
        }
        table.print();
        let path = format!("results/fig1_{}.csv", device.name);
        csv.save(&path).unwrap();
        println!("wrote {path}");

        // The paper's qualitative claim: the last core is nearly free of
        // benefit for a single container.
        let t = |c: f64| {
            let sched = CpuScheduler::new(&device);
            sched
                .run(&[JobSpec { container_id: 0, frames: 720, cpus: c, ready_at_s: 0.0 }])
                .makespan_s
        };
        let last_core_gain = 1.0 - t(device.cores) / t(device.cores - 1.0);
        println!(
            "last core adds only {:.1}% speedup (paper: 'slight improvement')",
            last_core_gain * 100.0
        );
    }
}
