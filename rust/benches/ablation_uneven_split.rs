//! A3 — ablation: equal vs uneven data splits.
//!
//! The paper splits data into EQUAL segments (§V step 1). This ablation
//! quantifies why: skewed segments create stragglers — the makespan is
//! set by the largest segment while other containers idle, wasting the
//! energy-efficiency gain. Equal split is optimal for homogeneous
//! containers.

use divide_and_save::bench::{banner, Table};
use divide_and_save::device::{DeviceSpec, PowerSensor};
use divide_and_save::energy::meter_schedule;
use divide_and_save::sched::{CpuScheduler, JobSpec};
use divide_and_save::workload::{split_weighted, Segment};

fn run_split(device: &DeviceSpec, segments: &[Segment]) -> (f64, f64) {
    let k = segments.len();
    let cpus = device.cores / k as f64;
    let jobs: Vec<JobSpec> = segments
        .iter()
        .map(|s| JobSpec {
            container_id: s.index as u64,
            frames: s.len,
            cpus,
            ready_at_s: 0.0,
        })
        .collect();
    let schedule = CpuScheduler::new(device).run(&jobs);
    let rep = meter_schedule(device, &PowerSensor::default(), &schedule);
    (rep.time_s, rep.energy_j)
}

fn main() {
    banner("A3", "equal vs uneven splits (TX2, k=4, 720 frames)");
    let device = DeviceSpec::tx2();

    let cases: Vec<(&str, Vec<f64>)> = vec![
        ("equal 1:1:1:1", vec![1.0, 1.0, 1.0, 1.0]),
        ("mild skew 1.5:1:1:1", vec![1.5, 1.0, 1.0, 1.0]),
        ("skew 2:1:1:1", vec![2.0, 1.0, 1.0, 1.0]),
        ("heavy 4:1:1:1", vec![4.0, 1.0, 1.0, 1.0]),
        ("extreme 8:1:1:1", vec![8.0, 1.0, 1.0, 1.0]),
    ];

    let mut table = Table::new(["split", "time_s", "energy_j", "T vs equal", "E vs equal"]);
    let mut base = (0.0, 0.0);
    let mut prev_t = 0.0;
    for (i, (name, weights)) in cases.iter().enumerate() {
        let segs = split_weighted(720, weights);
        let (t, e) = run_split(&device, &segs);
        if i == 0 {
            base = (t, e);
        }
        table.row([
            name.to_string(),
            format!("{t:.1}"),
            format!("{e:.1}"),
            format!("{:.3}", t / base.0),
            format!("{:.3}", e / base.1),
        ]);
        assert!(t >= prev_t - 1e-9, "more skew must not be faster");
        prev_t = t;
    }
    table.print();

    // equal must be strictly optimal under any tested skew
    let worst = run_split(&device, &split_weighted(720, &[8.0, 1.0, 1.0, 1.0]));
    assert!(worst.0 > base.0 * 1.5, "heavy skew should badly straggle");
    println!("\nequal split is optimal; 8:1:1:1 skew costs {:.0}% extra time —", (worst.0 / base.0 - 1.0) * 100.0);
    println!("justifies the paper's equal-segment design (§V step 1) ✓");
}
