//! A4 — extension ablation: does the paper's result survive DVFS modes
//! and thermal limits?
//!
//! The paper pins the default power mode and runs 30-s bursts (no
//! thermal stress). Deployments care about both knobs, so this bench
//! sweeps (power mode x k) and checks (a) splitting wins in EVERY mode,
//! (b) sustained serving never crosses the thermal envelope on either
//! board at the paper's operating points.

use divide_and_save::bench::{banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::device::dvfs::PowerMode;
use divide_and_save::device::thermal::ThermalModel;
use divide_and_save::device::DeviceSpec;

fn main() {
    banner("A4", "DVFS modes x k, thermal envelope");

    for base in DeviceSpec::all() {
        let thermal = ThermalModel::for_device(base.name);
        println!("\n-- {} --", base.name);
        let mut table = Table::new([
            "mode", "k", "time_s", "energy_j", "power_w", "steadyC", "throttles?",
        ]);
        for mode in PowerMode::modes_for(&base) {
            let dev = mode.apply(&base);
            let ks = [1usize, 2, dev.cores as usize];
            let mut energies = Vec::new();
            for &k in &ks {
                let mut cfg = ExperimentConfig::default();
                cfg.device = dev.clone();
                cfg.containers = k;
                let r = run_sim(&cfg).unwrap();
                let t_ss = thermal.steady_state_c(r.avg_power_w);
                let throttles = t_ss > thermal.t_throttle_c;
                energies.push(r.energy_j);
                table.row([
                    mode.name.to_string(),
                    k.to_string(),
                    format!("{:.0}", r.time_s),
                    format!("{:.0}", r.energy_j),
                    format!("{:.1}", r.avg_power_w),
                    format!("{t_ss:.0}"),
                    if throttles { "YES".into() } else { "no".to_string() },
                ]);
                assert!(
                    !throttles,
                    "{} {} k={k}: sustained serving would throttle",
                    base.name,
                    mode.name
                );
            }
            // splitting must win on energy in every mode
            assert!(
                *energies.last().unwrap() < energies[0],
                "{} {}: split does not save energy",
                base.name,
                mode.name
            );
        }
        table.print();
        println!("splitting saves energy in every power mode; no operating point throttles ✓");
    }
}
