//! A8 — ablation: fixed-mode vs joint (mode, k) planner on the serving
//! engine.
//!
//! The planner redesign makes the power mode part of the decision: a
//! `JointPlanner` searches the mode×k grid for the minimum predicted
//! energy under a completion-time budget (the job's deadline when it
//! has one, the fixed-mode plan's own time otherwise). Two scenarios,
//! asserted at runtime:
//!
//! (a) **Single-job drain on TX2 modes.** Two short clips and one long
//!     job with a loose deadline arrive together; the shorts drain and
//!     the survivor absorbs the whole device. The fixed planner races
//!     to idle at MAXP; the joint planner downclocks the now-private
//!     device to MAXQ (cubic dynamic-power saving) and **strictly saves
//!     energy with zero deadline misses in both runs** — the p99-vs-SLO
//!     row does not regress (raw p99 grows by design: that is the
//!     deadline slack being spent, race-to-idle vs slow-and-steady).
//! (b) **A5 bursty trace (no deadlines).** With no slack to spend, the
//!     joint plan may only move when it is at least as fast AND at most
//!     as expensive as the fixed-mode plan (its dominance guarantee),
//!     so energy and p99 must be no worse than the fixed planner's.

use divide_and_save::bench::{a5_bursty_mixed_jobs, banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::router::SplitPolicy;
use divide_and_save::coordinator::{Coordinator, PlannerKind};
use divide_and_save::device::DeviceSpec;
use divide_and_save::server::{
    EngineConfig, EngineJob, EngineOutcome, GrantPolicy, ServingEngine, SplitDecider,
};
use divide_and_save::util::stats::summarize;
use divide_and_save::workload::TaskProfile;

fn run_engine(
    device: DeviceSpec,
    jobs: Vec<EngineJob>,
    kind: PlannerKind,
) -> EngineOutcome {
    let mut base = ExperimentConfig::default();
    base.device = device.clone();
    let planner = kind.build(base.clone(), SplitPolicy::Fixed(4));
    let mut coordinator = Coordinator::with_planner(base, planner);
    let mut cfg = EngineConfig::single_node(device);
    cfg.max_concurrent_jobs = 3;
    cfg.grant_policy = GrantPolicy::Elastic;
    ServingEngine::new(cfg, jobs, SplitDecider::Coordinator(&mut coordinator))
        .run()
        .unwrap()
}

/// The drain workload: two short clips plus one long job whose deadline
/// carries ~2.4x slack over the fixed planner's drain time.
fn drain_jobs() -> Vec<EngineJob> {
    let mut long = EngineJob::new(0, 0.0, 720, TaskProfile::yolo_tiny());
    long.deadline_s = Some(600.0);
    let mut s1 = EngineJob::new(1, 0.0, 24, TaskProfile::yolo_tiny());
    s1.deadline_s = Some(60.0);
    let mut s2 = EngineJob::new(2, 0.0, 24, TaskProfile::yolo_tiny());
    s2.deadline_s = Some(60.0);
    vec![long, s1, s2]
}

fn deadline_misses(out: &EngineOutcome, deadline_of: impl Fn(u64) -> Option<f64>) -> usize {
    out.completed
        .iter()
        .filter(|c| deadline_of(c.id).is_some_and(|d| c.finish_s > d + 1e-6))
        .count()
}

fn p99(out: &EngineOutcome) -> f64 {
    let latencies: Vec<f64> = out.completed.iter().map(|c| c.latency_s()).collect();
    summarize(&latencies).p99
}

fn main() {
    banner("A8", "fixed-mode vs joint (mode, k) planner");

    // ---- (a) single-job drain on TX2 modes ---------------------------
    banner("A8a", "single-job drain (TX2, 3 slots, elastic, 600 s deadline slack)");
    let fixed = run_engine(DeviceSpec::tx2(), drain_jobs(), PlannerKind::Fixed);
    let joint = run_engine(DeviceSpec::tx2(), drain_jobs(), PlannerKind::Joint);
    let drain_deadline = |id: u64| Some(if id == 0 { 600.0 } else { 60.0 });
    let mut table = Table::new([
        "planner", "energy_j", "p99_s", "deadline_misses", "mode_switches",
    ]);
    for (name, out) in [("fixed", &fixed), ("joint", &joint)] {
        table.row([
            name.to_string(),
            format!("{:.0}", out.node_energy_j[0]),
            format!("{:.1}", p99(out)),
            format!("{}", deadline_misses(out, drain_deadline)),
            format!("{}", out.mode_switches),
        ]);
    }
    table.print();
    assert!(
        joint.node_energy_j[0] < fixed.node_energy_j[0] * 0.9,
        "joint {:.0} J must strictly undercut fixed {:.0} J on the drain",
        joint.node_energy_j[0],
        fixed.node_energy_j[0]
    );
    assert_eq!(deadline_misses(&fixed, drain_deadline), 0);
    assert_eq!(
        deadline_misses(&joint, drain_deadline),
        0,
        "the downclock may only spend slack, never miss the SLO"
    );
    assert!(joint.mode_switches >= 1, "the drain must downclock");
    assert_eq!(fixed.mode_switches, 0);
    println!("\n(a) the draining TX2 downclocks to MAXQ: strictly less energy, zero");
    println!("    deadline misses in both runs — the p99-vs-SLO row does not regress");
    println!("    (raw p99 grows by exactly the slack the planner chose to spend) ✓");

    // ---- (b) A5 bursty trace, no deadlines ---------------------------
    banner("A8b", "A5 bursty MMPP trace (Orin, 3 slots, elastic, no deadlines)");
    let fixed = run_engine(DeviceSpec::orin(), a5_bursty_mixed_jobs(80), PlannerKind::Fixed);
    let joint = run_engine(DeviceSpec::orin(), a5_bursty_mixed_jobs(80), PlannerKind::Joint);
    let mut table = Table::new(["planner", "energy_kj", "p99_s", "mode_switches"]);
    for (name, out) in [("fixed", &fixed), ("joint", &joint)] {
        table.row([
            name.to_string(),
            format!("{:.2}", out.node_energy_j[0] / 1e3),
            format!("{:.2}", p99(out)),
            format!("{}", out.mode_switches),
        ]);
    }
    table.print();
    assert_eq!(fixed.completed.len(), joint.completed.len());
    assert!(
        joint.node_energy_j[0] <= fixed.node_energy_j[0] * 1.01,
        "no deadline slack to spend: joint energy {:.0} J must not exceed fixed {:.0} J",
        joint.node_energy_j[0],
        fixed.node_energy_j[0]
    );
    assert!(
        p99(&joint) <= p99(&fixed) * 1.05 + 1e-9,
        "joint p99 {:.2}s must not regress vs fixed {:.2}s",
        p99(&joint),
        p99(&fixed)
    );
    println!("\n(b) without deadlines the joint planner's dominance guarantee holds on");
    println!("    the session: energy and p99 no worse than the fixed-mode planner ✓");
}
