//! E6 — §VI closing claim: "We also applied the proposed splitting
//! method to a simple CNN inference task. Splitting the input data
//! (images) between containers led to similar improvements."
//!
//! Sweeps containers for the simple-CNN task on both devices and checks
//! the improvements track the YOLO ones.

use divide_and_save::bench::{banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::device::DeviceSpec;
use divide_and_save::workload::TaskProfile;

fn ratios(device: &DeviceSpec, task: TaskProfile, k_max: usize) -> Vec<(usize, f64, f64)> {
    let mut cfg = ExperimentConfig::default();
    cfg.device = device.clone();
    cfg.task = task;
    cfg.containers = 1;
    let bench = run_sim(&cfg).unwrap();
    (1..=k_max)
        .map(|k| {
            let mut c = cfg.clone();
            c.containers = k;
            let r = run_sim(&c).unwrap();
            let (t, e, _) = r.normalized(&bench);
            (k, t, e)
        })
        .collect()
}

fn main() {
    banner("E6 / §VI", "simple-CNN splitting vs YOLO splitting");
    for device in DeviceSpec::all() {
        let k_max = device.memory.max_containers(720).min(6);
        let yolo = ratios(&device, TaskProfile::yolo_tiny(), k_max);
        let cnn = ratios(&device, TaskProfile::simple_cnn(), k_max);

        println!("\n-- {} --", device.name);
        let mut table =
            Table::new(["k", "yolo T/T1", "cnn T/T1", "yolo E/E1", "cnn E/E1"]);
        for ((k, ty, ey), (_, tc, ec)) in yolo.iter().zip(&cnn) {
            table.row([
                k.to_string(),
                format!("{ty:.3}"),
                format!("{tc:.3}"),
                format!("{ey:.3}"),
                format!("{ec:.3}"),
            ]);
            // "similar improvements": same direction, within a few % —
            // the ratio structure is task-independent in both the model
            // and the paper's account.
            assert!((ty - tc).abs() < 0.05, "k={k}: time ratios diverge");
            assert!((ey - ec).abs() < 0.05, "k={k}: energy ratios diverge");
        }
        table.print();
        let best_cnn_e = cnn.iter().map(|&(_, _, e)| e).fold(f64::INFINITY, f64::min);
        assert!(best_cnn_e < 0.95, "CNN splitting must save energy");
        println!("simple-CNN best energy ratio {best_cnn_e:.3} — splitting helps ✓");
    }
}
