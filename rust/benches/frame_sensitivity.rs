//! E7 — §IV claim: "the *number of frames* in a video has the greatest
//! impact on the energy and time needed for YOLO inference. Other
//! characteristics of a video, such as the frame size, the bitrate, or
//! even the number of objects per frame, have minimal effect".
//!
//! Sweeps each attribute independently through the cost model and (for
//! frame count) the simulator, and checks cost responds only to frames.

use divide_and_save::bench::{banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::workload::Video;

fn time_for(video: Video) -> f64 {
    let mut cfg = ExperimentConfig::default();
    cfg.video = video;
    cfg.containers = 4;
    run_sim(&cfg).unwrap().time_s
}

fn main() {
    banner("E7 / §IV", "frame count dominates; size/bitrate/objects don't");

    // 1) frames: cost scales ~linearly
    let mut table = Table::new(["frames", "time_s", "s_per_frame"]);
    let mut per_frame = Vec::new();
    for frames in [180usize, 360, 720, 1440] {
        let t = time_for(Video::with_frames("f", frames, 24.0));
        per_frame.push(t / frames as f64);
        table.row([frames.to_string(), format!("{t:.1}"), format!("{:.4}", t / frames as f64)]);
    }
    table.print();
    let spread = (per_frame.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - per_frame.iter().cloned().fold(f64::INFINITY, f64::min))
        / per_frame[0];
    assert!(spread < 0.02, "per-frame cost must be ~constant, spread {spread:.3}");
    println!("per-frame cost constant within {:.1}% across 180..1440 frames ✓\n", spread * 100.0);

    // 2) other attributes: zero effect by construction (documented),
    //    verified through the public API.
    let base = Video::paper_default();
    let t0 = time_for(base.clone());
    let mut table = Table::new(["variant", "time_s", "delta"]);
    table.row(["baseline 1280x720@4000kbps".to_string(), format!("{t0:.1}"), "-".into()]);
    for (name, v) in [
        ("4K frame size", {
            let mut v = base.clone();
            v.width = 3840;
            v.height = 2160;
            v
        }),
        ("10x bitrate", {
            let mut v = base.clone();
            v.bitrate_kbps = 40_000;
            v
        }),
        ("10x objects/frame", {
            let mut v = base.clone();
            v.objects_per_frame = 30.0;
            v
        }),
    ] {
        let t = time_for(v);
        table.row([name.to_string(), format!("{t:.1}"), format!("{:+.2}", t - t0)]);
        assert!(
            (t - t0).abs() < 1e-9,
            "{name} changed inference cost — violates the paper's §IV finding"
        );
    }
    table.print();
    println!("frame size / bitrate / objects have no cost effect ✓ (matches §IV)");
}
