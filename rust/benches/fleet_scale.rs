//! FS — fleet-scale hot-path macro bench: the slab DES core, the
//! interned plan cache and power-of-two placement under a trace-driven
//! load (~1k simulated nodes, ~100k jobs), plus the sharded-fleet
//! macro comparison (~10k nodes, ~1M jobs: unsharded engine vs
//! per-shard event loops behind the energy-conscious router), with the
//! saved-baseline workflow from `divide_and_save::bench`.
//!
//! Usage (through `cargo bench --bench fleet_scale -- <flags>`):
//!   --save-baseline <name>   persist this run as rust/BENCH_<name>.json
//!   --baseline <name>        compare against a saved baseline; exits
//!                            nonzero on a >25% des_events_per_sec
//!                            regression (other deltas are reported but
//!                            only warn — model-side metrics are
//!                            deterministic, machine-side ones noisy)
//!   --smoke                  reduced sizes for CI smoke runs
//!   --shards <n>             shard count for the sharded macro run
//!                            (default 8; CI smokes both 1 and 4)
//!   --strict                 enforce the absolute perf floors
//!                            (>=1M DES events/sec, <1us cached plans)

use std::time::Instant;

use divide_and_save::bench::{
    banner, compare_to_baseline, load_baseline, save_baseline, BenchArgs, Metric, Table,
};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::planner::{PlanRequest, Planner};
use divide_and_save::coordinator::router::SplitPolicy;
use divide_and_save::coordinator::{FixedModePlanner, OnlineOptimizer};
use divide_and_save::device::DeviceSpec;
use divide_and_save::sched::EventQueue;
use divide_and_save::server::{
    run_sharded, EngineConfig, EngineJob, FleetDecider, PlacementPolicy, ServingEngine,
    ShardedConfig, ShardedOutcome, SplitDecider,
};
use divide_and_save::util::rng::Rng;
use divide_and_save::workload::{ArrivalProcess, TaskProfile};

/// Slab DES core under the engine's steady-state churn: a standing
/// population of events; every pop schedules a replacement, and every
/// 4th replacement is cancelled and rescheduled (the regrant pattern).
/// Returns events popped per second.
fn des_queue_events_per_sec(ops: usize) -> f64 {
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut rng = Rng::new(97);
    for i in 0..1024u64 {
        let _ = q.push(rng.f64() * 10.0, i);
    }
    let t0 = Instant::now();
    let mut pops = 0u64;
    while (pops as usize) < ops {
        let (t, _) = q.pop().expect("population is self-sustaining");
        pops += 1;
        let h = q.push(t + 0.1 + rng.f64(), pops);
        if pops % 4 == 0 && q.cancel(h) {
            let _ = q.push(t + 0.2 + rng.f64(), pops);
        }
    }
    ops as f64 / t0.elapsed().as_secs_f64()
}

/// Warm-cache planner lookup: one probe populates the interned
/// decision cache, then every subsequent plan is a packed-key hash hit.
/// Returns mean nanoseconds per cached plan.
fn cached_plan_ns(iters: usize) -> f64 {
    let base = ExperimentConfig { device: DeviceSpec::orin(), ..ExperimentConfig::default() };
    let mut planner =
        FixedModePlanner::new(base, SplitPolicy::Online(OnlineOptimizer::default()));
    let req = PlanRequest::new(DeviceSpec::orin(), TaskProfile::yolo_tiny(), 96);
    planner.plan(&req).expect("probe"); // the one miss
    let t0 = Instant::now();
    for _ in 0..iters {
        let plan = planner.plan(&req).expect("cached plan");
        std::hint::black_box(&plan);
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let stats = planner.cache_stats();
    assert_eq!(stats.misses, 1, "warm loop must never re-probe");
    assert_eq!(stats.hits, iters as u64);
    ns
}

struct FleetRun {
    wall_s: f64,
    des_events: u64,
    jobs: usize,
    mean_latency_s: f64,
    energy_per_job_j: f64,
}

/// Trace-driven fleet macro run: `nodes` Orin nodes behind power-of-two
/// placement, Poisson arrivals at ~45% per-node utilization, each job
/// split at its node's energy-optimal k.
fn fleet_macro(nodes: usize, jobs: usize) -> FleetRun {
    let mut cfg = EngineConfig::single_node(DeviceSpec::orin());
    cfg.nodes = vec![DeviceSpec::orin(); nodes];
    cfg.placement = PlacementPolicy::PowerOfTwo;
    let rate_per_s = 0.2 * nodes as f64; // ~45% of per-node capacity
    let mut rng = Rng::new(31);
    let engine_jobs: Vec<EngineJob> = ArrivalProcess::Poisson { rate_per_s }
        .arrivals(jobs, &mut rng)
        .into_iter()
        .enumerate()
        .map(|(i, t)| EngineJob::new(i as u64, t, 96, TaskProfile::yolo_tiny()))
        .collect();
    let t0 = Instant::now();
    let outcome = ServingEngine::new(cfg, engine_jobs, SplitDecider::PerNodeOptimal)
        .run()
        .expect("fleet run");
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(outcome.completed.len(), jobs);
    let mean_latency_s = outcome
        .completed
        .iter()
        .map(|c| c.latency_s())
        .sum::<f64>()
        / jobs as f64;
    FleetRun {
        wall_s,
        des_events: outcome.des_events,
        jobs,
        mean_latency_s,
        energy_per_job_j: outcome.node_energy_j.iter().sum::<f64>() / jobs as f64,
    }
}

/// Build the sharded macro config + job trace (same workload shape as
/// `fleet_macro`, one level up in scale) and run it.
fn sharded_macro(nodes: usize, jobs: usize, shards: usize) -> (ShardedOutcome, f64) {
    let mut cfg = EngineConfig::single_node(DeviceSpec::orin());
    cfg.nodes = vec![DeviceSpec::orin(); nodes];
    cfg.placement = PlacementPolicy::PowerOfTwo;
    let rate_per_s = 0.2 * nodes as f64;
    let mut rng = Rng::new(31);
    let engine_jobs: Vec<EngineJob> = ArrivalProcess::Poisson { rate_per_s }
        .arrivals(jobs, &mut rng)
        .into_iter()
        .enumerate()
        .map(|(i, t)| EngineJob::new(i as u64, t, 96, TaskProfile::yolo_tiny()))
        .collect();
    let scfg = ShardedConfig::new(cfg, shards);
    let t0 = Instant::now();
    let out = run_sharded(&scfg, engine_jobs, FleetDecider::PerNodeOptimal)
        .expect("sharded fleet run");
    let wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(out.outcome.completed.len(), jobs);
    (out, wall_s)
}

fn main() {
    let args = BenchArgs::parse_env();
    let (des_ops, plan_iters, nodes, jobs) = if args.smoke {
        (100_000, 20_000, 100, 5_000)
    } else {
        (1_000_000, 200_000, 1_000, 100_000)
    };

    banner("FS", "fleet-scale hot paths (slab DES, plan cache, p2c placement)");

    let des_rate = des_queue_events_per_sec(des_ops);
    println!("slab DES queue: {:.2}M events/sec over {des_ops} churn ops", des_rate / 1e6);

    let plan_ns = cached_plan_ns(plan_iters);
    println!("cached plan lookup: {plan_ns:.0} ns (n={plan_iters})");

    let fleet = fleet_macro(nodes, jobs);
    let fleet_rate = fleet.des_events as f64 / fleet.wall_s;
    let admission_us = fleet.wall_s / fleet.jobs as f64 * 1e6;
    println!(
        "fleet macro ({nodes} nodes, {jobs} jobs): {:.2}s wall, {} DES events \
         ({:.2}M events/sec), {admission_us:.1} us/job end to end",
        fleet.wall_s,
        fleet.des_events,
        fleet_rate / 1e6
    );

    // Sharded macro: the same workload shape one level up in scale,
    // unsharded engine vs per-shard event loops + two-level routing.
    let (big_nodes, big_jobs) = if args.smoke { (200, 10_000) } else { (10_000, 1_000_000) };
    let shards = args.shards.unwrap_or(8).max(1);
    banner(
        "FS-SHARD",
        &format!("sharded fleet macro ({big_nodes} nodes, {big_jobs} jobs, {shards} shards)"),
    );
    let (ref_out, ref_wall) = sharded_macro(big_nodes, big_jobs, 1);
    println!(
        "1 shard (reference): {ref_wall:.2}s wall, {} DES events ({:.2}M events/sec)",
        ref_out.outcome.des_events,
        ref_out.outcome.des_events as f64 / ref_wall / 1e6
    );
    let (out, wall) =
        if shards > 1 { sharded_macro(big_nodes, big_jobs, shards) } else { (ref_out, ref_wall) };
    let speedup = ref_wall / wall;
    let sharded_rate = out.outcome.des_events as f64 / wall;
    let sharded_admission_us = wall / big_jobs as f64 * 1e6;
    let sharded_latency_s = out
        .outcome
        .completed
        .iter()
        .map(|c| c.latency_s())
        .sum::<f64>()
        / big_jobs as f64;
    let sharded_energy_j =
        out.outcome.node_energy_j.iter().sum::<f64>() / big_jobs as f64;
    println!(
        "{shards} shard(s): {wall:.2}s wall ({speedup:.2}x vs 1 shard), {:.2}M events/sec, \
         {sharded_admission_us:.1} us/job, {} overflow reroutes",
        sharded_rate / 1e6,
        out.overflow_reroutes
    );
    let mut st = Table::new(["shard", "nodes", "jobs", "des_events", "Mev/s", "q_peak", "energy_kJ"]);
    for s in &out.per_shard {
        st.row([
            format!("{}", s.shard),
            format!("{}", s.nodes),
            format!("{}", s.jobs),
            format!("{}", s.des_events),
            format!("{:.2}", s.des_events as f64 / wall / 1e6),
            format!("{}", s.max_queue_depth),
            format!("{:.1}", s.energy_j / 1e3),
        ]);
    }
    st.print();

    let metrics = vec![
        Metric::higher("des_events_per_sec", des_rate),
        Metric::lower("cached_plan_ns", plan_ns),
        Metric::higher("fleet_events_per_sec", fleet_rate),
        Metric::lower("admission_decision_us", admission_us),
        Metric::lower("fleet_mean_latency_s", fleet.mean_latency_s),
        Metric::lower("fleet_energy_per_job_j", fleet.energy_per_job_j),
        Metric::lower("sharded_wall_s", wall),
        Metric::higher("sharded_events_per_sec", sharded_rate),
        Metric::higher("shard_speedup", speedup),
        Metric::lower("sharded_admission_us", sharded_admission_us),
        Metric::lower("sharded_mean_latency_s", sharded_latency_s),
        Metric::lower("sharded_energy_per_job_j", sharded_energy_j),
    ];

    let mut t = Table::new(["metric", "value"]);
    for m in &metrics {
        t.row([m.name.as_str(), &format!("{:.3}", m.value)]);
    }
    t.print();

    if let Some(name) = &args.baseline {
        match load_baseline(name).expect("loading baseline") {
            None => println!("\nno saved baseline {name:?} — skipping comparison"),
            Some(base) => {
                let (table, failures) = compare_to_baseline(&metrics, &base, 0.25);
                println!("\nvs baseline {name:?}:\n{table}");
                for f in &failures {
                    eprintln!("regression: {f}");
                }
                // The CI gate is the DES core's throughput; the other
                // deltas are informational (model metrics shift only
                // with intentional model changes, machine metrics are
                // host-dependent).
                if failures.iter().any(|f| f.starts_with("des_events_per_sec")) {
                    eprintln!("des_events_per_sec regressed more than 25% — failing");
                    std::process::exit(1);
                }
            }
        }
    }

    if let Some(name) = &args.save_baseline {
        let path = save_baseline(name, &metrics).expect("saving baseline");
        println!("\nsaved baseline to {}", path.display());
    }

    if args.strict {
        assert!(
            des_rate >= 1.0e6,
            "DES core must sustain >=1M events/sec, got {des_rate:.0}"
        );
        assert!(plan_ns < 1_000.0, "cached plans must stay sub-microsecond, got {plan_ns:.0} ns");
    }
}
