//! E2/E3/E4 — paper Fig. 3a (normalized time), 3b (normalized energy),
//! 3c (normalized average power) vs container count, on TX2 (k ≤ 6) and
//! AGX Orin (k ≤ 12), with the paper's reported anchors printed beside
//! our measurements.

use divide_and_save::bench::{banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::device::DeviceSpec;
use divide_and_save::util::csv::CsvWriter;

/// Paper anchors read from §VI text: (k, T/T1, E/E1, P/P1), NaN = not
/// reported.
fn paper_anchors(device: &str) -> Vec<(usize, f64, f64, f64)> {
    match device {
        "jetson-tx2" => vec![
            (2, 0.81, 0.90, f64::NAN),
            (4, 0.75, 0.85, 1.13),
        ],
        _ => vec![
            (2, 0.57, 0.75, f64::NAN),
            (4, 0.38, 0.60, f64::NAN),
            (12, 0.30, 0.57, 1.84),
        ],
    }
}

fn main() {
    banner("E2-E4 / Fig.3", "normalized time/energy/power vs containers");
    for device in DeviceSpec::all() {
        let k_max = device.memory.max_containers(720);
        println!("\n-- {} (k = 1..{k_max}) --", device.name);

        let mut cfg = ExperimentConfig::default();
        cfg.device = device.clone();
        cfg.containers = 1;
        let bench = run_sim(&cfg).unwrap();

        let mut table = Table::new(["k", "T/T1", "E/E1", "P/P1"]);
        let mut csv = CsvWriter::new(["k", "t_ratio", "e_ratio", "p_ratio"]);
        let mut series = Vec::new();
        for k in 1..=k_max {
            let mut c = cfg.clone();
            c.containers = k;
            let r = run_sim(&c).unwrap();
            let (t, e, p) = r.normalized(&bench);
            series.push((k, t, e, p));
            table.row([k.to_string(), format!("{t:.3}"), format!("{e:.3}"), format!("{p:.3}")]);
            csv.row([k.to_string(), t.to_string(), e.to_string(), p.to_string()]);
        }
        table.print();
        let path = format!("results/fig3_{}.csv", device.name);
        csv.save(&path).unwrap();

        println!("\npaper anchors vs measured:");
        let mut cmp = Table::new(["k", "metric", "paper", "measured", "abs err"]);
        for (k, tp, ep, pp) in paper_anchors(device.name) {
            let &(_, t, e, p) = series.iter().find(|(kk, ..)| *kk == k).unwrap();
            for (name, paper, got) in [("time", tp, t), ("energy", ep, e), ("power", pp, p)] {
                if paper.is_nan() {
                    continue;
                }
                cmp.row([
                    k.to_string(),
                    name.to_string(),
                    format!("{paper:.2}"),
                    format!("{got:.3}"),
                    format!("{:.3}", (got - paper).abs()),
                ]);
                assert!(
                    (got - paper).abs() < 0.05,
                    "{} k={k} {name}: {got:.3} vs paper {paper}",
                    device.name
                );
            }
        }
        cmp.print();

        // Qualitative shape checks from §VI.
        if device.name == "jetson-tx2" {
            let t4 = series[3].1;
            let t6 = series[5].1;
            assert!(t6 > t4, "TX2 must degrade beyond k=4 (t4={t4:.3} t6={t6:.3})");
            println!("TX2 degradation beyond 4 containers reproduced ✓");
        } else {
            let t4 = series[3].1;
            let t12 = series[11].1;
            assert!(t12 < t4 && (t4 - t12) < 0.12, "Orin curve must flatten past k=4");
            println!("Orin flattening beyond 4 containers reproduced ✓");
        }
    }
}
