//! A5 — extension: serving under realistic traffic.
//!
//! The paper evaluates one video at a time; an MEC server sees a
//! stream. This bench drives the coordinator with Poisson and bursty
//! MMPP arrivals (motion-triggered-camera style) at the same mean rate
//! and compares split policies on p95 latency, throughput and energy —
//! showing the paper's method is exactly what keeps a loaded server
//! inside its latency budget (service time drops ~4x on Orin).

use divide_and_save::bench::{banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::router::SplitPolicy;
use divide_and_save::coordinator::{Coordinator, OnlineOptimizer};
use divide_and_save::device::DeviceSpec;
use divide_and_save::server::{serve, ServeConfig};
use divide_and_save::workload::ArrivalProcess;

fn main() {
    banner("A5", "serving under Poisson + bursty MMPP traffic (Orin, SIM)");

    let mk_base = || {
        let mut c = ExperimentConfig::default();
        c.device = DeviceSpec::orin();
        c
    };
    // Mean arrival: one 96-frame job every 12 s; bursts at 6x.
    let poisson = ArrivalProcess::Poisson { rate_per_s: 1.0 / 12.0 };
    let mmpp = ArrivalProcess::Mmpp {
        calm_rate_per_s: 0.05,
        burst_rate_per_s: 0.35,
        mean_calm_s: 130.0,
        mean_burst_s: 20.0,
    };
    assert!((mmpp.mean_rate() - poisson.mean_rate()).abs() / poisson.mean_rate() < 0.35);

    let mut table = Table::new([
        "traffic", "policy", "p50_lat_s", "p95_lat_s", "frames/s", "energy_kj",
    ]);
    let mut p95 = std::collections::BTreeMap::new();
    for (tname, arrival) in [("poisson", poisson.clone()), ("mmpp-bursty", mmpp.clone())] {
        for (pname, policy) in [
            ("k=1 (naive)", SplitPolicy::Fixed(1)),
            ("k=4", SplitPolicy::Fixed(4)),
            ("online", SplitPolicy::Online(OnlineOptimizer::default())),
        ] {
            let mut coordinator = Coordinator::new(mk_base(), policy);
            let report = serve(
                &mut coordinator,
                &ServeConfig {
                    jobs: 60,
                    arrival: Some(arrival.clone()),
                    frames_per_job: 96,
                    seed: 11,
                    ..Default::default()
                },
            )
            .unwrap();
            p95.insert((tname, pname), report.latency.p95);
            table.row([
                tname.to_string(),
                pname.to_string(),
                format!("{:.1}", report.latency.p50),
                format!("{:.1}", report.latency.p95),
                format!("{:.1}", report.frames_per_s),
                format!("{:.1}", report.total_energy_j / 1e3),
            ]);
        }
    }
    table.print();

    for tname in ["poisson", "mmpp-bursty"] {
        let naive = p95[&(tname, "k=1 (naive)")];
        let online = p95[&(tname, "online")];
        assert!(
            online < naive,
            "{tname}: online p95 {online:.1}s should beat naive {naive:.1}s"
        );
    }
    println!("\nonline split policy beats the naive single container on p95 latency");
    println!("under both traffic shapes ✓ (splitting = headroom under load)");
}
