//! A5 — extension: serving under realistic traffic, on the
//! event-driven engine.
//!
//! The paper evaluates one video at a time; an MEC server sees a
//! stream. This bench drives the concurrent serving engine with Poisson
//! and bursty MMPP arrivals (motion-triggered-camera style) at the same
//! mean rate and compares split policies on tail latency, throughput
//! and energy — splitting is exactly what keeps a loaded server inside
//! its latency budget, and the engine's aggregated metering (idle paid
//! once per device) is what makes the energy numbers honest.

use divide_and_save::bench::{a5_bursty_arrivals, banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::router::SplitPolicy;
use divide_and_save::coordinator::{Coordinator, OnlineOptimizer};
use divide_and_save::device::DeviceSpec;
use divide_and_save::server::{serve, GrantPolicy, ServeConfig};
use divide_and_save::workload::ArrivalProcess;

fn main() {
    banner("A5", "serving under Poisson + bursty MMPP traffic (Orin, engine)");

    let mk_base = || {
        let mut c = ExperimentConfig::default();
        c.device = DeviceSpec::orin();
        c
    };
    // Mean arrival: one 96-frame job every 12 s; bursts at 6x. The
    // MMPP operating point is the shared A5 definition the A7/A8
    // ablations reuse (`bench::a5_bursty_arrivals`).
    let poisson = ArrivalProcess::Poisson { rate_per_s: 1.0 / 12.0 };
    let mmpp = a5_bursty_arrivals();
    assert!((mmpp.mean_rate() - poisson.mean_rate()).abs() / poisson.mean_rate() < 0.35);

    let mut table = Table::new([
        "traffic", "policy", "p50_lat_s", "p95_lat_s", "frames/s", "energy_kj", "util",
    ]);
    let mut p95 = std::collections::BTreeMap::new();
    let mut energy = std::collections::BTreeMap::new();
    for (tname, arrival) in [("poisson", poisson.clone()), ("mmpp-bursty", mmpp.clone())] {
        for (pname, policy) in [
            ("k=1 (naive)", SplitPolicy::Fixed(1)),
            ("k=4", SplitPolicy::Fixed(4)),
            ("online", SplitPolicy::Online(OnlineOptimizer::default())),
        ] {
            let mut coordinator = Coordinator::new(mk_base(), policy);
            let report = serve(
                &mut coordinator,
                &ServeConfig {
                    jobs: 60,
                    arrival: Some(arrival.clone()),
                    frames_per_job: 96,
                    seed: 11,
                    ..Default::default()
                },
            )
            .unwrap();
            p95.insert((tname, pname), report.latency.p95);
            energy.insert((tname, pname), report.total_energy_j);
            table.row([
                tname.to_string(),
                pname.to_string(),
                format!("{:.1}", report.latency.p50),
                format!("{:.1}", report.latency.p95),
                format!("{:.1}", report.frames_per_s),
                format!("{:.1}", report.total_energy_j / 1e3),
                format!("{:.2}", report.node_utilization[0]),
            ]);
        }
    }
    table.print();

    for tname in ["poisson", "mmpp-bursty"] {
        let naive_p95 = p95[&(tname, "k=1 (naive)")];
        let online_p95 = p95[&(tname, "online")];
        assert!(
            online_p95 < naive_p95,
            "{tname}: online p95 {online_p95:.1}s should beat naive {naive_p95:.1}s"
        );
        let naive_e = energy[&(tname, "k=1 (naive)")];
        let online_e = energy[&(tname, "online")];
        assert!(
            online_e < naive_e,
            "{tname}: online energy {online_e:.0}J should beat naive {naive_e:.0}J"
        );
    }
    println!("\nonline split policy beats the naive single container on BOTH p95");
    println!("latency and energy under both traffic shapes ✓ (splitting = headroom)");

    // --- overload: where the serial clock diverges, the engine holds --
    banner("A5b", "overload: serial loop vs concurrent engine (1 job / 2.5 s)");
    let arrival = ArrivalProcess::Deterministic { gap_s: 2.5 };
    let overload_cfg = |conc: usize| ServeConfig {
        jobs: 150,
        arrival: Some(arrival.clone()),
        frames_per_job: 96,
        seed: 13,
        max_concurrent_jobs: conc,
        ..Default::default()
    };
    let mut serial = Coordinator::new(mk_base(), SplitPolicy::Fixed(4));
    let r_serial = serve(&mut serial, &overload_cfg(1)).unwrap();
    let mut engine = Coordinator::new(mk_base(), SplitPolicy::Online(OnlineOptimizer::default()));
    let r_engine = serve(&mut engine, &overload_cfg(3)).unwrap();
    let mut elastic = Coordinator::new(mk_base(), SplitPolicy::Online(OnlineOptimizer::default()));
    let r_elastic = serve(
        &mut elastic,
        &ServeConfig { grant_policy: GrantPolicy::Elastic, ..overload_cfg(3) },
    )
    .unwrap();

    let mut t2 = Table::new(["loop", "p50_lat_s", "p99_lat_s", "max_lat_s", "queue_max", "energy_kj"]);
    for (name, r) in [
        ("serial k=4", &r_serial),
        ("engine online", &r_engine),
        ("engine online+elastic", &r_elastic),
    ] {
        t2.row([
            name.to_string(),
            format!("{:.1}", r.latency.p50),
            format!("{:.1}", r.latency.p99),
            format!("{:.1}", r.latency.max),
            format!("{}", r.max_queue_depth),
            format!("{:.2}", r.total_energy_j / 1e3),
        ]);
    }
    t2.print();
    assert!(
        r_engine.latency.p99 < r_serial.latency.p99 / 2.0,
        "engine p99 {:.1}s vs serial {:.1}s",
        r_engine.latency.p99,
        r_serial.latency.p99
    );
    // Uniform jobs at a sustainable rate never overlap on the engine,
    // so the elastic policy has no event to regrant on: it must
    // degenerate to the fixed policy exactly (no churn when the load
    // doesn't call for it). The fixed-vs-elastic ablation where they DO
    // diverge is A7 (`ablation_elastic_grant`).
    assert_eq!(r_elastic.regrants, 0, "sustainable uniform load must not churn");
    assert!((r_elastic.latency.p99 - r_engine.latency.p99).abs() < 1e-9);
    assert!((r_elastic.total_energy_j - r_engine.total_energy_j).abs() < 1e-6);
    println!("\nat an offered load where the serial clock diverges, the event-driven");
    println!("engine reaches steady state with bounded p99 ✓ (elastic grants");
    println!("degenerate to fixed here — no overlap, no churn ✓)");
}
