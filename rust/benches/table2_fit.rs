//! E5 — paper Table II: fit the convex models (quadratic on TX2,
//! exponential on Orin) to the normalized sweep and print the fitted
//! formulae beside the paper's, with reference values and R².

use divide_and_save::bench::{banner, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::device::DeviceSpec;
use divide_and_save::modelfit::{
    convexity_ok, fit_exponential, fit_quadratic, r2_of_fit, FittedModel,
};

struct PaperRow {
    metric: &'static str,
    reference: &'static str,
    model: &'static str,
}

fn paper_rows(device: &str) -> Vec<PaperRow> {
    match device {
        "jetson-tx2" => vec![
            PaperRow { metric: "Time", reference: "325 s", model: "0.026x^2 - 0.21x + 1.17" },
            PaperRow { metric: "Energy", reference: "942 J", model: "0.015x^2 - 0.12x + 1.10" },
            PaperRow { metric: "Power", reference: "2.9 W", model: "-0.016x^2 + 0.12x + 0.90" },
        ],
        _ => vec![
            PaperRow { metric: "Time", reference: "54 s", model: "0.33 + 1.77e^{-0.98x}" },
            PaperRow { metric: "Energy", reference: "700 J", model: "0.59 + 1.14e^{-1.03x}" },
            PaperRow { metric: "Power", reference: "13 W", model: "1.85 - 1.24e^{-0.38x}" },
        ],
    }
}

fn main() {
    banner("E5 / Table II", "fitted models (x = number of containers)");
    for device in DeviceSpec::all() {
        let k_max = device.memory.max_containers(720);
        let mut cfg = ExperimentConfig::default();
        cfg.device = device.clone();
        cfg.containers = 1;
        let bench = run_sim(&cfg).unwrap();

        let mut xs = Vec::new();
        let mut series: Vec<Vec<f64>> = vec![Vec::new(), Vec::new(), Vec::new()];
        for k in 1..=k_max {
            let mut c = cfg.clone();
            c.containers = k;
            let r = run_sim(&c).unwrap();
            let (t, e, p) = r.normalized(&bench);
            xs.push(k as f64);
            series[0].push(t);
            series[1].push(e);
            series[2].push(p);
        }

        println!("\n-- {} --", device.name);
        let use_exponential = device.name == "jetson-agx-orin";
        let refs = [
            format!("{:.0} s", bench.time_s),
            format!("{:.0} J", bench.energy_j),
            format!("{:.1} W", bench.avg_power_w),
        ];
        let mut table = Table::new(["metric", "paper ref", "our ref", "paper model", "our model", "R^2"]);
        for (i, row) in paper_rows(device.name).iter().enumerate() {
            let ys = &series[i];
            let model = if use_exponential {
                FittedModel::Exponential(fit_exponential(&xs, ys).expect("exp fit"))
            } else {
                FittedModel::Quadratic(fit_quadratic(&xs, ys).expect("quad fit"))
            };
            let r2 = r2_of_fit(&model, &xs, ys);
            // TX2's quadratic has to straddle the k>4 interference kink
            // (the paper's own Fig. 3 shows the same tension), so its
            // bar is slightly lower than Orin's smooth exponential.
            let r2_floor = if use_exponential { 0.97 } else { 0.94 };
            assert!(
                r2 > r2_floor,
                "{} {}: fit R^2 {r2:.3} below {r2_floor}",
                device.name,
                row.metric
            );
            // paper: time & energy models are convex (decreasing benefit)
            if row.metric != "Power" {
                assert!(
                    convexity_ok(ys, 0.02),
                    "{} {} curve should be convex",
                    device.name,
                    row.metric
                );
            }
            table.row([
                row.metric.to_string(),
                row.reference.to_string(),
                refs[i].clone(),
                row.model.to_string(),
                model.describe(),
                format!("{r2:.4}"),
            ]);
        }
        table.print();
    }
    println!("\n(Coefficients need not match the paper digit-for-digit — the substrate");
    println!(" is a calibrated simulator — but family, convexity, reference values and");
    println!(" the fitted curves' shape reproduce Table II.)");
}
