//! A6 — extension: the paper's distributed-edge future work, on the
//! shared serving engine.
//!
//! A heterogeneous cluster (2x TX2 + 1x AGX Orin) serves a stream of
//! 120-frame video jobs through the event-driven engine, every node
//! running divide-and-save internally (its energy-optimal k). Compares
//! placement policies on total energy, makespan and mean latency —
//! energy now comes from each device's aggregated busy timeline (idle
//! paid once per device busy period, nothing while asleep).

use divide_and_save::bench::{banner, Table};
use divide_and_save::cluster::{Cluster, PlacementPolicy};
use divide_and_save::device::DeviceSpec;
use divide_and_save::server::GrantPolicy;
use divide_and_save::util::rng::Rng;
use divide_and_save::workload::ArrivalProcess;

fn main() {
    banner("A6", "multi-device placement (2x TX2 + 1x Orin, 40 jobs, engine)");

    let mut rng = Rng::new(21);
    let arrivals =
        ArrivalProcess::Poisson { rate_per_s: 1.0 / 15.0 }.arrivals(40, &mut rng);
    let jobs: Vec<(f64, usize)> = arrivals.into_iter().map(|t| (t, 120)).collect();

    let devices = || vec![DeviceSpec::tx2(), DeviceSpec::tx2(), DeviceSpec::orin()];

    let mut table = Table::new([
        "policy", "energy_kj", "makespan_s", "mean_lat_s", "jobs/node", "util/node",
    ]);
    let mut results = Vec::new();
    for (name, policy) in [
        ("round-robin", PlacementPolicy::RoundRobin),
        ("least-loaded", PlacementPolicy::LeastLoaded),
        ("energy-aware", PlacementPolicy::EnergyAware),
    ] {
        let report = Cluster::new(devices(), policy).run(&jobs).unwrap();
        table.row([
            name.to_string(),
            format!("{:.2}", report.total_energy_j / 1e3),
            format!("{:.0}", report.makespan_s),
            format!("{:.1}", report.mean_latency_s),
            format!("{:?}", report.jobs_per_node),
            format!(
                "{:?}",
                report
                    .node_utilization
                    .iter()
                    .map(|u| (u * 100.0).round() / 100.0)
                    .collect::<Vec<_>>()
            ),
        ]);
        results.push((name, report));
    }
    table.print();

    let energy = |n: &str| results.iter().find(|(m, _)| *m == n).unwrap().1.total_energy_j;
    assert!(energy("energy-aware") < energy("round-robin"));
    assert!(energy("energy-aware") <= energy("least-loaded") + 1e-6);
    println!("\nenergy-aware placement (EASE-style, using the Table II device models)");
    println!("minimizes cluster energy; the paper's models generalize to placement ✓");

    // --- elastic grants across the cluster: mixed burst, 2 slots/node --
    banner("A6b", "fixed vs elastic grants on the cluster (mixed burst, 2 slots/node)");
    // One long clip and one short clip per node, all at t=0: with fixed
    // grants every long job keeps its half-device admission share after
    // its short neighbor drains; elastic regrants expand it.
    let burst: Vec<(f64, usize)> = vec![
        (0.0, 720),
        (0.0, 48),
        (0.0, 720),
        (0.0, 48),
        (0.0, 720),
        (0.0, 48),
    ];
    let run_grant = |grant_policy: GrantPolicy| {
        let mut c = Cluster::new(devices(), PlacementPolicy::RoundRobin);
        c.max_concurrent_jobs = 2;
        c.grant_policy = grant_policy;
        c.run(&burst).unwrap()
    };
    let fixed = run_grant(GrantPolicy::Fixed);
    let elastic = run_grant(GrantPolicy::Elastic);
    let mut t3 = Table::new(["grants", "energy_kj", "makespan_s", "mean_lat_s"]);
    for (name, r) in [("fixed", &fixed), ("elastic", &elastic)] {
        t3.row([
            name.to_string(),
            format!("{:.2}", r.total_energy_j / 1e3),
            format!("{:.0}", r.makespan_s),
            format!("{:.1}", r.mean_latency_s),
        ]);
    }
    t3.print();
    assert!(
        elastic.makespan_s < fixed.makespan_s,
        "elastic makespan {:.0}s should beat fixed {:.0}s",
        elastic.makespan_s,
        fixed.makespan_s
    );
    assert!(
        elastic.total_energy_j < fixed.total_energy_j,
        "elastic energy {:.0}J should beat fixed {:.0}J",
        elastic.total_energy_j,
        fixed.total_energy_j
    );
    println!("\nelastic grants expand each node's surviving long job after its short");
    println!("neighbor drains: lower makespan AND lower energy on every node ✓");
}
