//! A6 — extension: the paper's distributed-edge future work, on the
//! shared serving engine.
//!
//! A heterogeneous cluster (2x TX2 + 1x AGX Orin) serves a stream of
//! 120-frame video jobs through the event-driven engine, every node
//! running divide-and-save internally (its energy-optimal k). Compares
//! placement policies on total energy, makespan and mean latency —
//! energy now comes from each device's aggregated busy timeline (idle
//! paid once per device busy period, nothing while asleep).

use divide_and_save::bench::{banner, Table};
use divide_and_save::cluster::{Cluster, PlacementPolicy};
use divide_and_save::device::DeviceSpec;
use divide_and_save::util::rng::Rng;
use divide_and_save::workload::ArrivalProcess;

fn main() {
    banner("A6", "multi-device placement (2x TX2 + 1x Orin, 40 jobs, engine)");

    let mut rng = Rng::new(21);
    let arrivals =
        ArrivalProcess::Poisson { rate_per_s: 1.0 / 15.0 }.arrivals(40, &mut rng);
    let jobs: Vec<(f64, usize)> = arrivals.into_iter().map(|t| (t, 120)).collect();

    let devices = || vec![DeviceSpec::tx2(), DeviceSpec::tx2(), DeviceSpec::orin()];

    let mut table = Table::new([
        "policy", "energy_kj", "makespan_s", "mean_lat_s", "jobs/node", "util/node",
    ]);
    let mut results = Vec::new();
    for (name, policy) in [
        ("round-robin", PlacementPolicy::RoundRobin),
        ("least-loaded", PlacementPolicy::LeastLoaded),
        ("energy-aware", PlacementPolicy::EnergyAware),
    ] {
        let report = Cluster::new(devices(), policy).run(&jobs).unwrap();
        table.row([
            name.to_string(),
            format!("{:.2}", report.total_energy_j / 1e3),
            format!("{:.0}", report.makespan_s),
            format!("{:.1}", report.mean_latency_s),
            format!("{:?}", report.jobs_per_node),
            format!(
                "{:?}",
                report
                    .node_utilization
                    .iter()
                    .map(|u| (u * 100.0).round() / 100.0)
                    .collect::<Vec<_>>()
            ),
        ]);
        results.push((name, report));
    }
    table.print();

    let energy = |n: &str| results.iter().find(|(m, _)| *m == n).unwrap().1.total_energy_j;
    assert!(energy("energy-aware") < energy("round-robin"));
    assert!(energy("energy-aware") <= energy("least-loaded") + 1e-6);
    println!("\nenergy-aware placement (EASE-style, using the Table II device models)");
    println!("minimizes cluster energy; the paper's models generalize to placement ✓");
}
