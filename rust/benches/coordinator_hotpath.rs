//! L3 performance microbenches: the coordinator hot paths (§Perf).
//!
//! SIM experiment throughput (the sweep benches iterate hundreds of
//! runs), splitter, combiner-scale NMS/decode, JSON parse, DES core.
//! Also, when artifacts exist, the REAL-path per-batch inference cost of
//! the pallas-lowered vs pure-jnp-lowered HLO (L1/L2 perf comparison).

use divide_and_save::bench::{banner, bench, Table};
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::detect::{decode_output, nms, NmsParams};
use divide_and_save::sched::EventQueue;
use divide_and_save::util::json::Json;
use divide_and_save::util::rng::Rng;
use divide_and_save::workload::{split_even, FrameGenerator};

fn main() {
    banner("L3 perf", "coordinator hot paths");
    let mut results = Vec::new();

    // Full SIM experiment (720 frames, k=4): the unit of every sweep.
    let cfg = {
        let mut c = ExperimentConfig::default();
        c.containers = 4;
        c
    };
    results.push(bench("sim_experiment_720f_k4", 3, 30, || {
        let r = run_sim(&cfg).unwrap();
        std::hint::black_box(r.energy_j);
    }));

    // Coarse-sensor variant (100 ms sampling) — the accuracy/speed knob.
    let cfg_coarse = {
        let mut c = cfg.clone();
        c.sensor_period_s = 0.1;
        c
    };
    results.push(bench("sim_experiment_coarse_sensor", 3, 30, || {
        std::hint::black_box(run_sim(&cfg_coarse).unwrap().energy_j);
    }));

    //

    // Splitter at serving rates.
    results.push(bench("split_even_720x12_x1000", 2, 20, || {
        for _ in 0..1000 {
            std::hint::black_box(split_even(720, 12));
        }
    }));

    // Decode + NMS on a realistic head buffer (540 boxes/frame).
    let mut rng = Rng::new(1);
    let boxes: Vec<f32> = (0..540 * 25).map(|_| rng.f64() as f32).collect();
    let params = NmsParams::default();
    results.push(bench("decode_nms_540boxes", 5, 50, || {
        let cands = decode_output(&boxes, 25, 0, params.score_threshold);
        std::hint::black_box(nms(cands, &params));
    }));

    // Frame generation (REAL-path input production).
    let gen = FrameGenerator::yolo(0);
    results.push(bench("framegen_batch4", 3, 50, || {
        std::hint::black_box(gen.batch(0, 4));
    }));

    // Manifest-sized JSON parse.
    let manifest_like = std::fs::read_to_string("artifacts/manifest.json")
        .unwrap_or_else(|_| r#"{"variants": []}"#.to_string());
    results.push(bench("json_parse_manifest", 5, 100, || {
        std::hint::black_box(Json::parse(&manifest_like).unwrap());
    }));

    // DES core: 100k events.
    results.push(bench("des_100k_events", 2, 20, || {
        let mut q = EventQueue::new();
        let mut r = Rng::new(2);
        for _ in 0..100_000 {
            q.push(r.range_f64(0.0, 1e6), 0u32);
        }
        while q.pop().is_some() {}
    }));

    println!();
    for r in &results {
        println!("{}", r.report_line());
    }

    // REAL-path L1/L2 comparison if artifacts are present.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use divide_and_save::runtime::{Engine, Manifest};
        println!("\n-- L1/L2: pallas-lowered vs pure-jnp-lowered HLO (PJRT CPU, batch 4) --");
        let m = Manifest::load("artifacts").unwrap();
        let gen = FrameGenerator::yolo(9);
        let input = gen.batch(0, 4);
        let mut table = Table::new(["variant", "mean ms/batch", "ms/frame"]);
        for variant in ["yolo_tiny_b4", "yolo_tiny_ref_b4"] {
            let e = Engine::load(&m, variant).unwrap();
            let r = bench(variant, 2, 10, || {
                std::hint::black_box(e.run(&input).unwrap());
            });
            table.row([
                variant.to_string(),
                format!("{:.1}", r.stats.mean * 1e3),
                format!("{:.1}", r.stats.mean * 1e3 / 4.0),
            ]);
        }
        table.print();
        println!("(interpret-mode pallas lowers to HLO while-loops; the gap vs the");
        println!(" XLA-fused reference bounds the CPU-substitute cost — on real TPU the");
        println!(" Mosaic path replaces it. See DESIGN.md §Perf.)");
    }
}
