"""L2 correctness: tiny-YOLO / simple-CNN through Pallas kernels vs the
pure-jnp reference network, plus structural invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def yolo_params():
    return model.init_yolo_params()


@pytest.fixture(scope="module")
def cnn_params():
    return model.init_cnn_params()


def _frames(batch, shape, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), (batch,) + shape)


@pytest.mark.parametrize("batch", [1, 2, 4])
def test_yolo_pallas_matches_ref(yolo_params, batch):
    x = _frames(batch, model.YOLO_INPUT)
    c, f = model.yolo_tiny_apply(yolo_params, x)
    cr, fr = model.yolo_tiny_apply_ref(yolo_params, x)
    np.testing.assert_allclose(c, cr, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(f, fr, rtol=1e-3, atol=1e-4)


def test_yolo_output_shapes(yolo_params):
    c, f = model.yolo_tiny_apply(yolo_params, _frames(2, model.YOLO_INPUT))
    assert c.shape == (2, 6 * 6 * 3, model.NATTR)
    assert f.shape == (2, 12 * 12 * 3, model.NATTR)


def test_yolo_deterministic(yolo_params):
    x = _frames(1, model.YOLO_INPUT)
    a1, _ = model.yolo_tiny_apply(yolo_params, x)
    a2, _ = model.yolo_tiny_apply(yolo_params, x)
    np.testing.assert_array_equal(a1, a2)


def test_yolo_batch_consistency(yolo_params):
    """Each frame's detections must be independent of its batch peers —
    THE property the paper's splitting method relies on."""
    x = _frames(4, model.YOLO_INPUT)
    c4, f4 = model.yolo_tiny_apply(yolo_params, x)
    c1, f1 = model.yolo_tiny_apply(yolo_params, x[2:3])
    np.testing.assert_allclose(c4[2:3], c1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(f4[2:3], f1, rtol=1e-4, atol=1e-5)


def test_init_reproducible():
    p1 = model.init_yolo_params(seed=7)
    p2 = model.init_yolo_params(seed=7)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])
    p3 = model.init_yolo_params(seed=8)
    assert any(not np.array_equal(p1[k], p3[k]) for k in p1)


def test_param_count_matches_architecture(yolo_params):
    expected = 0
    for _n, k, cin, cout, _s, _a in model.YOLO_BACKBONE:
        expected += k * k * cin * cout + cout
    head_ch = model.NUM_ANCHORS * model.NATTR
    expected += 128 * head_ch + head_ch + 64 * head_ch + head_ch
    assert model.param_count(yolo_params) == expected


def test_flops_positive_and_conv_dominated():
    fl = model.yolo_flops_per_frame()
    assert fl > 10_000_000  # a real CNN, not a toy stub
    assert model.cnn_flops_per_frame() < fl


@pytest.mark.parametrize("batch", [1, 4])
def test_cnn_pallas_matches_ref(cnn_params, batch):
    x = _frames(batch, model.CNN_INPUT)
    (got,) = model.simple_cnn_apply(cnn_params, x)
    (want,) = model.simple_cnn_apply_ref(cnn_params, x)
    assert got.shape == (batch, 10)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_make_jitted_variants():
    for m, batch in (("yolo_tiny", 2), ("simple_cnn", 4)):
        fn, args = model.make_jitted(m, batch)
        out = jax.jit(fn).lower(*args)
        assert out is not None
    with pytest.raises(ValueError):
        model.make_jitted("resnet50", 1)
