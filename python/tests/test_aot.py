"""AOT path: lowered HLO text must round-trip through the XLA text parser
and execute with the SAME numerics as the jitted python function — this is
exactly what the rust runtime does at serve time."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def cpu_client():
    return xc.make_cpu_client()


def _roundtrip_execute(cpu_client, text, x):
    """Parse HLO text back and execute on the raw XLA CPU client."""
    mod = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(mod.as_serialized_hlo_module_proto())
    mlir_mod = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    if isinstance(mlir_mod, str):
        mlir_mod = mlir_mod.encode()
    devices = xc.DeviceList(tuple(cpu_client.devices()))
    exe = cpu_client.compile_and_load(mlir_mod, devices)
    outs = exe.execute([cpu_client.buffer_from_pyval(np.asarray(x))])
    return [np.asarray(o) for o in outs]


def test_hlo_text_is_parseable_and_has_constants():
    text, entry = aot.lower_variant("yolo_tiny_b1", "yolo_tiny", 1, False)
    assert "ENTRY" in text
    # weights must be baked in full, never elided
    assert "constant({...})" not in text
    assert entry["input"]["shape"] == [1, 96, 96, 3]
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_roundtrip_numerics_yolo(cpu_client):
    text, _ = aot.lower_variant("yolo_tiny_b2", "yolo_tiny", 2, False)
    x = jax.random.uniform(jax.random.PRNGKey(3), (2,) + model.YOLO_INPUT)
    fn, _ = model.make_jitted("yolo_tiny", 2)
    want_c, want_f = jax.jit(fn)(x)
    got = _roundtrip_execute(cpu_client, text, x)
    # return_tuple=True -> flat list of the tuple leaves
    np.testing.assert_allclose(got[0], want_c, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got[1], want_f, rtol=1e-4, atol=1e-5)


def test_roundtrip_numerics_cnn(cpu_client):
    text, _ = aot.lower_variant("simple_cnn_b1", "simple_cnn", 1, False)
    x = jax.random.uniform(jax.random.PRNGKey(4), (1,) + model.CNN_INPUT)
    fn, _ = model.make_jitted("simple_cnn", 1)
    (want,) = jax.jit(fn)(x)
    got = _roundtrip_execute(cpu_client, text, x)
    np.testing.assert_allclose(got[0], want, rtol=1e-4, atol=1e-5)


def test_manifest_schema():
    entries = []
    for name, m, b, use_ref in aot.VARIANTS[:1]:
        _, e = aot.lower_variant(name, m, b, use_ref)
        entries.append(e)
    e = entries[0]
    for key in ("name", "file", "model", "batch", "input", "outputs",
                "flops_per_frame", "param_count", "sha256"):
        assert key in e
    assert json.dumps(e)  # JSON-serializable


def test_pallas_and_ref_variants_agree(cpu_client):
    """The pallas-lowered HLO and the pure-jnp-lowered HLO are different
    programs that must compute the same function."""
    xp = jax.random.uniform(jax.random.PRNGKey(5), (1,) + model.YOLO_INPUT)
    t_pallas, _ = aot.lower_variant("a", "yolo_tiny", 1, False)
    t_ref, _ = aot.lower_variant("b", "yolo_tiny", 1, True)
    got_p = _roundtrip_execute(cpu_client, t_pallas, xp)
    got_r = _roundtrip_execute(cpu_client, t_ref, xp)
    np.testing.assert_allclose(got_p[0], got_r[0], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(got_p[1], got_r[1], rtol=1e-3, atol=1e-4)
