"""L1 correctness: Pallas GEMM kernel vs pure-jnp oracle.

Hypothesis sweeps shapes, activations and block sizes; the kernel must be
bit-close to the oracle for every draw (the CORE correctness signal for
the whole stack — every conv and dense layer routes through this kernel).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 160),
    n=st.integers(1, 96),
    act=st.sampled_from(matmul.ACTIVATIONS),
)
def test_matmul_matches_ref_shapes(m, k, n, act):
    x = _rand(0, (m, k))
    w = _rand(1, (k, n))
    b = _rand(2, (n,))
    got = matmul.matmul_bias_act(x, w, b, act=act)
    want = ref.matmul_bias_act_ref(x, w, b, act=act)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 64, 128]),
    bn=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
)
def test_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the tiling choice."""
    x = _rand(3, (77, 53))
    w = _rand(4, (53, 19))
    b = _rand(5, (19,))
    got = matmul.matmul_bias_act(
        x, w, b, act="leaky_relu", block_m=bm, block_n=bn, block_k=bk
    )
    want = ref.matmul_bias_act_ref(x, w, b, act="leaky_relu")
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_matmul_bf16():
    x = _rand(0, (64, 64), jnp.bfloat16)
    w = _rand(1, (64, 64), jnp.bfloat16)
    b = _rand(2, (64,), jnp.bfloat16)
    got = matmul.matmul_bias_act(x, w, b, act="linear")
    want = ref.matmul_bias_act_ref(x, w, b, act="linear")
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), rtol=5e-2, atol=5e-2
    )


def test_matmul_single_element():
    x = jnp.array([[2.0]])
    w = jnp.array([[3.0]])
    b = jnp.array([1.0])
    got = matmul.matmul_bias_act(x, w, b)
    np.testing.assert_allclose(got, [[7.0]], rtol=1e-6)


def test_matmul_rejects_bad_shapes():
    with pytest.raises((ValueError, TypeError)):
        matmul.matmul_bias_act(_rand(0, (4, 5)), _rand(1, (6, 3)), _rand(2, (3,)))
    with pytest.raises((ValueError, TypeError)):
        matmul.matmul_bias_act(_rand(0, (4, 5)), _rand(1, (5, 3)), _rand(2, (4,)))


def test_matmul_rejects_bad_activation():
    with pytest.raises((ValueError, TypeError)):
        matmul.matmul_bias_act(
            _rand(0, (4, 4)), _rand(1, (4, 4)), _rand(2, (4,)), act="gelu"
        )


def test_leaky_relu_negative_slope():
    """Epilogue really is leaky (not plain) ReLU, slope 0.1 as in YOLO."""
    x = jnp.array([[-10.0, 10.0]])
    w = jnp.eye(2)
    b = jnp.zeros(2)
    got = matmul.matmul_bias_act(x, w, b, act="leaky_relu")
    np.testing.assert_allclose(got, [[-1.0, 10.0]], rtol=1e-6)


def test_vmem_footprint_within_tpu_budget():
    """Default blocks must fit VMEM (16 MiB on current TPUs) with

    double-buffering headroom (DESIGN.md §Perf)."""
    bytes_per_step = matmul.vmem_footprint_bytes()
    assert bytes_per_step * 2 < 16 * 1024 * 1024


def test_mxu_utilization_estimate_monotone():
    full = matmul.mxu_utilization_estimate(1024, 1024, 1024)
    ragged = matmul.mxu_utilization_estimate(1000, 1000, 1000)
    tiny = matmul.mxu_utilization_estimate(8, 8, 8)
    assert full == pytest.approx(1.0)
    assert 0 < ragged <= 1.0
    assert tiny < ragged
