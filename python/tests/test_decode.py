"""L1 correctness: detection-head decode kernel vs oracle + semantic
invariants (box centers in [0,1], scores are probabilities)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode, ref

jax.config.update("jax_platform_name", "cpu")


def _anchors(a):
    return jnp.linspace(0.05, 0.8, 2 * a, dtype=jnp.float32).reshape(a, 2)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 13),
    w=st.integers(1, 13),
    a=st.integers(1, 4),
    c=st.integers(1, 30),
)
def test_decode_matches_ref(b, h, w, a, c):
    nattr = 5 + c
    x = jax.random.normal(jax.random.PRNGKey(0), (b, h, w, a * nattr))
    anch = _anchors(a)
    got = decode.decode_head(x, anch, c)
    want = ref.decode_head_ref(x, anch, c)
    assert got.shape == (b, h * w * a, nattr)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_decode_semantics():
    """Centers in [0,1]; obj/cls in (0,1); zero logits land mid-cell."""
    b, h, w, a, c = 2, 4, 4, 3, 20
    x = jax.random.normal(jax.random.PRNGKey(1), (b, h, w, a * (5 + c))) * 3
    boxes = decode.decode_head(x, _anchors(a), c)
    bx, by = boxes[..., 0], boxes[..., 1]
    assert float(bx.min()) >= 0 and float(bx.max()) <= 1
    assert float(by.min()) >= 0 and float(by.max()) <= 1
    scores = boxes[..., 4:]
    assert float(scores.min()) > 0 and float(scores.max()) < 1

    zeros = jnp.zeros((1, 2, 2, a * (5 + c)))
    zb = decode.decode_head(zeros, _anchors(a), c)
    # sigmoid(0)=0.5 -> first cell center at 0.25 on a 2-cell grid
    np.testing.assert_allclose(zb[0, 0, 0], 0.25, rtol=1e-6)
    # wh = anchor * exp(0) = anchor
    np.testing.assert_allclose(zb[0, 0, 2:4], _anchors(a)[0], rtol=1e-6)


def test_decode_channel_mismatch_raises():
    x = jnp.zeros((1, 4, 4, 30))
    with pytest.raises(ValueError):
        decode.decode_head(x, _anchors(3), 20)  # needs 75 channels
