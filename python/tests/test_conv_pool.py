"""L1 correctness: conv2d (im2col+GEMM) and maxpool kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, pool, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(4, 24),
    w=st.integers(4, 24),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
)
def test_conv_matches_ref(b, h, w, cin, cout, k, stride, padding):
    if padding == "VALID" and (h < k or w < k):
        return
    x = _rand(0, (b, h, w, cin))
    wgt = _rand(1, (k, k, cin, cout))
    bias = _rand(2, (cout,))
    got = conv.conv2d_bias_act(x, wgt, bias, stride=stride, padding=padding)
    want = ref.conv2d_bias_act_ref(x, wgt, bias, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_conv_all_activations():
    x = _rand(0, (2, 8, 8, 3))
    wgt = _rand(1, (3, 3, 3, 4))
    bias = _rand(2, (4,))
    for act in ("linear", "leaky_relu", "relu", "sigmoid"):
        got = conv.conv2d_bias_act(x, wgt, bias, act=act)
        want = ref.conv2d_bias_act_ref(x, wgt, bias, act=act)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_conv_cin_mismatch_raises():
    with pytest.raises(ValueError):
        conv.conv2d_bias_act(_rand(0, (1, 8, 8, 3)), _rand(1, (3, 3, 4, 8)),
                             _rand(2, (8,)))


def test_conv_same_stride2_asymmetric_padding():
    """XLA SAME pads (0,1) for even input / stride 2 / k=3 — the bug class
    this guards against produced a 7.8 max abs error across the model."""
    x = _rand(0, (1, 96, 96, 3))
    wgt = _rand(1, (3, 3, 3, 16))
    bias = jnp.zeros((16,))
    got = conv.conv2d_bias_act(x, wgt, bias, stride=2, padding="SAME")
    want = ref.conv2d_bias_act_ref(x, wgt, bias, stride=2, padding="SAME")
    assert got.shape == (1, 48, 48, 16)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_conv_flops_formula():
    # 1x1 conv on 4x4: 2*16*cin*cout
    assert conv.conv_flops(4, 4, 1, 1, 8, 16) == 2 * 16 * 8 * 16
    assert conv.conv_flops(6, 6, 3, 3, 64, 128) == 2 * 36 * 9 * 64 * 128


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.sampled_from([2, 4, 6, 8, 12, 24]),
    w=st.sampled_from([2, 4, 6, 8, 12]),
    c=st.integers(1, 16),
)
def test_maxpool_matches_ref(b, h, w, c):
    x = _rand(7, (b, h, w, c))
    got = pool.maxpool2x2(x)
    want = ref.maxpool2x2_ref(x)
    assert got.shape == (b, h // 2, w // 2, c)
    np.testing.assert_allclose(got, want)


def test_maxpool_odd_raises():
    with pytest.raises(ValueError):
        pool.maxpool2x2(_rand(0, (1, 5, 4, 2)))


def test_maxpool_is_max_not_mean():
    x = jnp.array([[[[1.0], [2.0]], [[3.0], [4.0]]]])  # (1,2,2,1)
    np.testing.assert_allclose(pool.maxpool2x2(x), [[[[4.0]]]])
