"""Block-policy (§Perf) and analysis-tool tests: the auto-block choices
must respect VMEM budgets, stay correct under every policy branch, and
the shipped variants must lower to fusion-clean HLO."""

import jax
import pytest
from hypothesis import given, settings, strategies as st

from compile import analyze, aot, model
from compile.kernels import matmul

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=60, deadline=None)
@given(
    m=st.integers(1, 50_000),
    k=st.integers(1, 4096),
    n=st.integers(1, 1024),
)
def test_auto_blocks_respect_budgets(m, k, n):
    bm, bn, bk = matmul.auto_blocks(m, k, n)
    footprint = 4 * (bm * bk + bk * bn + bn + bm * bn)
    # single-step path uses the VMEM cap; tiled path the smaller budget
    assert footprint <= matmul.SINGLE_STEP_VMEM
    assert bm % 8 == 0 and bn % 8 == 0 and bk % 8 == 0
    assert bm >= 8 and bn >= 8 and bk >= 8


def test_single_step_for_model_gemms():
    """Every GEMM of the shipped b4 model takes the single-step path
    (the §Perf iteration-3 property that removed the while loops)."""
    for layer, m, k, n in analyze.gemm_shapes("yolo_tiny", 4):
        bm, bn, bk = matmul.auto_blocks(m, k, n)
        steps = -(-m // bm) * -(-n // bn) * -(-k // bk)
        assert steps == 1, f"{layer}: {steps} grid steps"


def test_tiled_path_kicks_in_for_large_problems():
    bm, bn, bk = matmul.auto_blocks(1_000_000, 1152, 128)
    assert bm < 1_000_000
    footprint = 4 * (bm * bk + bk * bn + bn + bm * bn)
    assert footprint <= matmul.TILE_VMEM_BUDGET


def test_tiled_path_is_still_correct():
    """Force the tiled branch explicitly and compare against the oracle
    (guards the path real YOLO sizes would take)."""
    import jax.numpy as jnp
    import numpy as np
    from compile.kernels import ref

    x = jax.random.normal(jax.random.PRNGKey(0), (300, 144), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (144, 48), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (48,), jnp.float32)
    got = matmul.matmul_bias_act(x, w, b, act="leaky_relu",
                                 block_m=64, block_n=16, block_k=32)
    want = ref.matmul_bias_act_ref(x, w, b, act="leaky_relu")
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_op_census_counts():
    text = """
  a.1 = f32[2,2]{1,0} dot(x, y), foo
  b.2 = f32[2,2]{1,0} add(a.1, a.1)
  c.3 = f32[2,2]{1,0} add(b.2, b.2)
"""
    ops = analyze.op_census(text)
    assert ops == {"dot": 1, "add": 2}


def test_fusion_health_flags():
    assert analyze.fusion_health({"dot": 3}) == []
    flags = analyze.fusion_health({"while": 2, "transpose": 1, "convolution": 4})
    assert len(flags) == 3


@pytest.mark.parametrize("name,model_name,batch,use_ref", aot.VARIANTS[:1])
def test_shipped_variant_is_fusion_clean(name, model_name, batch, use_ref):
    fn, args = model.make_jitted(model_name, batch, use_ref=use_ref)
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    ops = analyze.op_census(text)
    assert analyze.fusion_health(ops) == [], f"{name}: {analyze.fusion_health(ops)}"


def test_gemm_shapes_flops_consistency():
    """The analyzer's GEMM inventory must account for the model's
    analytic FLOPs exactly (2*M*K*N summed == flops_per_frame * batch)."""
    for model_name, per_frame in [
        ("yolo_tiny", model.yolo_flops_per_frame()),
        ("simple_cnn", model.cnn_flops_per_frame()),
    ]:
        batch = 4
        total = sum(2 * m * k * n for _l, m, k, n in analyze.gemm_shapes(model_name, batch))
        assert total == per_frame * batch, model_name
