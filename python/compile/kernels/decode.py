"""L1 Pallas kernel: YOLO detection-head decode.

Transforms a raw head tensor (B, H, W, A*(5+C)) into decoded boxes
(B, H*W*A, 5+C):

  bx = (sigmoid(tx) + cell_x) / W          by = (sigmoid(ty) + cell_y) / H
  bw = anchor_w * exp(tw)                  bh = anchor_h * exp(th)
  obj = sigmoid(to)                        cls_i = sigmoid(tc_i)

Everything is elementwise plus a broadcasted-iota for the cell offsets, so
the whole decode for one image is a single VMEM-resident block; fusing it
into the model avoids shipping raw logits back to HBM and re-reading them
for a separate activation pass.

The rust side (``detect::nms``) consumes these decoded boxes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(x_ref, anch_ref, o_ref, *, h: int, w: int, a: int, nattr: int):
    x = x_ref[...].reshape(h, w, a, nattr)  # (H, W, A, 5+C)
    anchors = anch_ref[...]  # (A, 2) in fractions of image size

    cell_y = jax.lax.broadcasted_iota(x.dtype, (h, w, a), 0)
    cell_x = jax.lax.broadcasted_iota(x.dtype, (h, w, a), 1)

    sig = jax.nn.sigmoid(x)
    bx = (sig[..., 0] + cell_x) / w
    by = (sig[..., 1] + cell_y) / h
    bw = anchors[:, 0] * jnp.exp(x[..., 2])
    bh = anchors[:, 1] * jnp.exp(x[..., 3])
    rest = sig[..., 4:]  # objectness + class scores

    out = jnp.concatenate(
        [
            bx[..., None],
            by[..., None],
            bw[..., None],
            bh[..., None],
            rest,
        ],
        axis=-1,
    )
    o_ref[...] = out.reshape(1, h * w * a, nattr).astype(o_ref.dtype)


def decode_head(x, anchors, num_classes: int):
    """Decode one detection head.

    Args:
      x: (B, H, W, A*(5+num_classes)) raw head output.
      anchors: (A, 2) anchor sizes as fractions of image size.
      num_classes: C.

    Returns:
      (B, H*W*A, 5+C) decoded boxes: [bx, by, bw, bh, obj, cls...],
      bx/by/bw/bh in [0,1] image fractions.
    """
    b, h, w, ch = x.shape
    a = anchors.shape[0]
    nattr = 5 + num_classes
    if ch != a * nattr:
        raise ValueError(f"head channels {ch} != A*(5+C) = {a * nattr}")
    kern = functools.partial(_decode_kernel, h=h, w=w, a=a, nattr=nattr)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, ch), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((a, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h * w * a, nattr), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h * w * a, nattr), x.dtype),
        interpret=True,
    )(x, anchors)
