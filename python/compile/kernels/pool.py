"""L1 Pallas kernel: 2x2/stride-2 max pooling.

One grid step per image; the (H, W, C) feature map is a single VMEM block
(largest map in tiny-YOLO is 24x24x32 = 73 KiB << 16 MiB VMEM), reduced
with a reshape-max — the VPU-friendly formulation (8x128 lanes operate on
the channel-minor layout).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref):
    x = x_ref[...]  # (1, H, W, C)
    _, h, w, c = x.shape
    x = x.reshape(h // 2, 2, w // 2, 2, c)
    o_ref[...] = jnp.max(x, axis=(1, 3)).reshape(1, h // 2, w // 2, c)


def maxpool2x2(x):
    """Max-pool NHWC input with 2x2 window, stride 2.

    H and W must be even (tiny-YOLO only pools even maps).
    """
    b, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2x2 needs even H,W; got {x.shape}")
    return pl.pallas_call(
        _maxpool_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h // 2, w // 2, c), x.dtype),
        interpret=True,
    )(x)
