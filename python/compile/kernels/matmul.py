"""L1 Pallas kernel: tiled matmul with fused bias + activation epilogue.

This is the compute hot-spot of the whole stack: every convolution in the
tiny-YOLO backbone is lowered to an im2col GEMM that lands here, and the
dense layers of the simple CNN call it directly.

TPU adaptation of the (normally CUDA) YOLO workload, per DESIGN.md
§Hardware-Adaptation:

  * the MXU systolic array is the compute primitive, so the kernel is a
    (bm, bk) x (bk, bn) block matmul, not a thread-per-output-pixel loop;
  * BlockSpec index maps express the HBM->VMEM streaming schedule that a
    CUDA implementation would write with shared-memory threadblocks;
  * the elementwise epilogue (bias add + leaky ReLU) is fused into the
    output block while it is still resident in VMEM, avoiding an HBM
    round-trip for the activation pass.

Kernels are always lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers the grid
into plain HLO (a fori loop of dynamic-slice/dot/dynamic-update-slice),
which the rust runtime executes unmodified.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block-shape policy (§Perf iterations 1-3, see EXPERIMENTS.md §Perf).
#
# AUTO-sized blocks instead of fixed 128^3 tiles, chosen by problem size:
#
#  1. If the WHOLE padded problem (x + w + bias + out) fits
#     SINGLE_STEP_VMEM (14 MiB of the 16 MiB TPU VMEM), use one grid
#     step with block = problem. For this paper's scaled tiny-YOLO every
#     GEMM at batch <= 4 qualifies — a legitimate whole-problem-in-VMEM
#     kernel. It also sidesteps interpret mode's dominant cost (a
#     full-array copy-back per grid step): 32.6 -> ~2 ms/frame measured.
#  2. Otherwise tile: full K and N extents if they fit their caps (the
#     MXU streams K-major without revisiting the output block), and
#     grow bm under TILE_VMEM_BUDGET, leaving headroom to double-buffer
#     the next x block. This is the path real YOLOv4-tiny sizes take;
#     block-shape invariance tests pin its correctness.
#
# Padding is to multiples of 8 (f32 sublane), NOT powers of two — pow2
# padding inflated K=288 to 512, nearly doubling HBM traffic (iteration
# 2's measured regression).
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128
SINGLE_STEP_VMEM = 14 * 1024 * 1024
TILE_VMEM_BUDGET = 4 * 1024 * 1024
MAX_BLOCK_M = 4096
MAX_BLOCK_N = 512
MAX_BLOCK_K = 2048


def _ceil8(v: int) -> int:
    return max(8, (v + 7) // 8 * 8)


def auto_blocks(m: int, k: int, n: int, bytes_per_elem: int = 4):
    """Pick (bm, bn, bk) for an (m,k)x(k,n) GEMM per the policy above."""
    mm, kk, nn = _ceil8(m), _ceil8(k), _ceil8(n)
    full = bytes_per_elem * (mm * kk + kk * nn + nn + mm * nn)
    if full <= SINGLE_STEP_VMEM:
        return mm, nn, kk
    bk = min(kk, MAX_BLOCK_K)
    bn = min(nn, MAX_BLOCK_N)
    bm = 8
    while bm < MAX_BLOCK_M and bm < mm:
        nxt = bm * 2
        footprint = bytes_per_elem * (nxt * bk + bk * bn + bn + nxt * bn)
        if footprint > TILE_VMEM_BUDGET:
            break
        bm = nxt
    return bm, bn, bk

LEAKY_SLOPE = 0.1

ACTIVATIONS = ("linear", "leaky_relu", "relu", "sigmoid")


def apply_act(y, act: str):
    """Elementwise epilogue used by the kernel and by ref.py."""
    if act == "linear":
        return y
    if act == "leaky_relu":
        return jnp.where(y >= 0, y, LEAKY_SLOPE * y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(y)
    raise ValueError(f"unknown activation {act!r}")


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str, k_steps: int):
    """Grid point (i, j, k): accumulate x[i,k] @ w[k,j] into the output
    block (revisited across k), apply bias + activation on the last k step
    while the block is still in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        o_ref[...] = apply_act(o_ref[...] + b_ref[...], act).astype(o_ref.dtype)


def _pad_to(x, multiple, axis):
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _fit_block(requested: int, dim: int) -> int:
    """Shrink a block edge for small problems: dim rounded up to a
    multiple of 8, clamped to [8, requested]."""
    return min(requested, _ceil8(dim))


@functools.partial(jax.jit, static_argnames=("act", "block_m", "block_n", "block_k"))
def matmul_bias_act(
    x,
    w,
    b,
    *,
    act: str = "linear",
    block_m=None,
    block_n=None,
    block_k=None,
):
    """``act(x @ w + b)`` as a tiled Pallas kernel.

    Args:
      x: (M, K) float array.
      w: (K, N) float array.
      b: (N,) float array, broadcast over rows.
      act: one of ``ACTIVATIONS``.
      block_m/n/k: tile edges; default None = ``auto_blocks`` policy.

    Returns:
      (M, N) array with the dtype of ``x``.
    """
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")

    auto_m, auto_n, auto_k = auto_blocks(m, k, n)
    bm = _fit_block(block_m or auto_m, m)
    bn = _fit_block(block_n or auto_n, n)
    bk = _fit_block(block_k or auto_k, k)

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    bp = _pad_to(b.reshape(1, n), bn, 1)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, act=act, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_footprint_bytes(
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    bytes_per_elem: int = 4,
) -> int:
    """Analytic VMEM bytes resident per grid step (x block + w block +
    bias row + output block). Used by the §Perf estimate and its test."""
    return bytes_per_elem * (
        block_m * block_k + block_k * block_n + block_n + block_m * block_n
    )


def mxu_utilization_estimate(
    m: int,
    k: int,
    n: int,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    mxu: int = 128,
) -> float:
    """Fraction of MXU lanes doing useful work for an (m,k)x(k,n) GEMM
    tiled with the given blocks: padding waste x tile-edge waste."""

    def ceil_div(a, b):
        return -(-a // b)

    eff_m = m / (ceil_div(m, block_m) * block_m)
    eff_n = n / (ceil_div(n, block_n) * block_n)
    eff_k = k / (ceil_div(k, block_k) * block_k)
    tile_m = min(block_m, mxu) / mxu
    tile_n = min(block_n, mxu) / mxu
    return eff_m * eff_n * eff_k * tile_m * tile_n
