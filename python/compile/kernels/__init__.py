"""L1 Pallas kernels (build-time only; lowered into the model HLO)."""

from . import conv, decode, matmul, pool, ref  # noqa: F401
