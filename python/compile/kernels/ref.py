"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``*_ref`` mirrors the public signature of the corresponding kernel in
matmul.py / conv.py / pool.py / decode.py; pytest + hypothesis assert
allclose between the two over shape/dtype sweeps (python/tests/).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LEAKY_SLOPE = 0.1


def apply_act_ref(y, act: str):
    if act == "linear":
        return y
    if act == "leaky_relu":
        return jnp.where(y >= 0, y, LEAKY_SLOPE * y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "sigmoid":
        return jax.nn.sigmoid(y)
    raise ValueError(f"unknown activation {act!r}")


def matmul_bias_act_ref(x, w, b, *, act: str = "linear"):
    return apply_act_ref(jnp.dot(x, w) + b, act)


def conv2d_bias_act_ref(x, w, b, *, stride: int = 1, padding: str = "SAME",
                        act: str = "leaky_relu"):
    """NHWC x HWIO convolution via lax.conv_general_dilated."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return apply_act_ref(y + b, act)


def maxpool2x2_ref(x):
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def decode_head_ref(x, anchors, num_classes: int):
    b, h, w, ch = x.shape
    a = anchors.shape[0]
    nattr = 5 + num_classes
    assert ch == a * nattr
    x = x.reshape(b, h, w, a, nattr)
    cell_y = jax.lax.broadcasted_iota(x.dtype, (h, w, a), 0)
    cell_x = jax.lax.broadcasted_iota(x.dtype, (h, w, a), 1)
    sig = jax.nn.sigmoid(x)
    bx = (sig[..., 0] + cell_x) / w
    by = (sig[..., 1] + cell_y) / h
    bw = anchors[:, 0] * jnp.exp(x[..., 2])
    bh = anchors[:, 1] * jnp.exp(x[..., 3])
    out = jnp.concatenate(
        [bx[..., None], by[..., None], bw[..., None], bh[..., None], sig[..., 4:]],
        axis=-1,
    )
    return out.reshape(b, h * w * a, nattr)
