"""L1: conv2d as im2col + the tiled Pallas GEMM kernel.

The convolution is re-expressed for the MXU (DESIGN.md
§Hardware-Adaptation): patches are extracted with static slices (a pure
data-movement reshuffle XLA folds into the surrounding program) and the
actual arithmetic — the (B*H'*W', kh*kw*Cin) x (kh*kw*Cin, Cout) GEMM
with fused bias + leaky-ReLU epilogue — runs in
``matmul.matmul_bias_act``.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import matmul


def extract_patches(x, kh: int, kw: int, stride: int, padding: str):
    """im2col: NHWC -> (B, H', W', kh*kw*Cin) with (ki, kj, cin) ordering,
    matching ``w.reshape(kh*kw*cin, cout)`` for HWIO weights."""
    b, h, w, c = x.shape
    if padding == "SAME":
        # XLA-style SAME: total = (out-1)*stride + k - in, split low/high
        # with the extra pixel on the high side (matches
        # lax.conv_general_dilated, which pads asymmetrically for
        # stride > 1 on even inputs).
        out_h = -(-h // stride)
        out_w = -(-w // stride)
        tot_h = max(0, (out_h - 1) * stride + kh - h)
        tot_w = max(0, (out_w - 1) * stride + kw - w)
        x = jnp.pad(
            x,
            (
                (0, 0),
                (tot_h // 2, tot_h - tot_h // 2),
                (tot_w // 2, tot_w - tot_w // 2),
                (0, 0),
            ),
        )
    elif padding == "VALID":
        out_h = (h - kh) // stride + 1
        out_w = (w - kw) // stride + 1
    else:
        raise ValueError(f"unknown padding {padding!r}")

    cols = []
    for ki in range(kh):
        for kj in range(kw):
            sl = x[
                :,
                ki : ki + (out_h - 1) * stride + 1 : stride,
                kj : kj + (out_w - 1) * stride + 1 : stride,
                :,
            ]
            cols.append(sl)
    return jnp.concatenate(cols, axis=-1), out_h, out_w


def conv2d_bias_act(
    x,
    w,
    b,
    *,
    stride: int = 1,
    padding: str = "SAME",
    act: str = "leaky_relu",
    block_m=None,
    block_n=None,
    block_k=None,
):
    """2-D convolution with fused bias + activation.

    Args:
      x: (B, H, W, Cin) NHWC input.
      w: (kh, kw, Cin, Cout) HWIO weights.
      b: (Cout,) bias.
      stride: spatial stride (same in both dims).
      padding: "SAME" or "VALID".
      act: activation name from ``matmul.ACTIVATIONS``.

    Returns:
      (B, H', W', Cout) output.
    """
    kh, kw, cin, cout = w.shape
    if x.shape[-1] != cin:
        raise ValueError(f"Cin mismatch: x {x.shape} vs w {w.shape}")
    patches, out_h, out_w = extract_patches(x, kh, kw, stride, padding)
    bsz = x.shape[0]
    lhs = patches.reshape(bsz * out_h * out_w, kh * kw * cin)
    rhs = w.reshape(kh * kw * cin, cout)
    y = matmul.matmul_bias_act(
        lhs, rhs, b, act=act, block_m=block_m, block_n=block_n, block_k=block_k
    )
    return y.reshape(bsz, out_h, out_w, cout)


def conv_flops(h_out: int, w_out: int, kh: int, kw: int, cin: int, cout: int) -> int:
    """MACs*2 per image for one conv layer (bias+act ignored)."""
    return 2 * h_out * w_out * kh * kw * cin * cout
