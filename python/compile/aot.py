"""AOT compile path: lower every (model, batch) variant to HLO *text* and
write an ``artifacts/manifest.json`` index the rust runtime consumes.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 rust crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Run once via ``make artifacts``; python never runs on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as M

# (variant name, model, batch, use_ref). The *_ref variants lower the
# pure-jnp network for the L2 perf comparison (EXPERIMENTS.md §Perf).
VARIANTS = [
    ("yolo_tiny_b1", "yolo_tiny", 1, False),
    ("yolo_tiny_b2", "yolo_tiny", 2, False),
    ("yolo_tiny_b4", "yolo_tiny", 4, False),
    ("yolo_tiny_b8", "yolo_tiny", 8, False),
    ("yolo_tiny_ref_b4", "yolo_tiny", 4, True),
    ("simple_cnn_b1", "simple_cnn", 1, False),
    ("simple_cnn_b8", "simple_cnn", 8, False),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the baked model weights MUST survive the
    # text round-trip (default elides them as ``constant({...})``, which
    # the rust-side parser would reject).
    return comp.as_hlo_text(print_large_constants=True)


def lower_variant(name: str, model: str, batch: int, use_ref: bool):
    fn, example_args = M.make_jitted(model, batch, use_ref=use_ref)
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)

    if model == "yolo_tiny":
        outputs = [
            {"name": "boxes_coarse", "shape": [batch, 108, M.NATTR]},
            {"name": "boxes_fine", "shape": [batch, 432, M.NATTR]},
        ]
        flops = M.yolo_flops_per_frame()
        params = M.param_count(M.init_yolo_params())
        in_shape = [batch, *M.YOLO_INPUT]
    else:
        outputs = [{"name": "logits", "shape": [batch, 10]}]
        flops = M.cnn_flops_per_frame()
        params = M.param_count(M.init_cnn_params())
        in_shape = [batch, *M.CNN_INPUT]

    entry = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "model": model,
        "batch": batch,
        "ref_kernels": use_ref,
        "input": {"shape": in_shape, "dtype": "f32"},
        "outputs": outputs,
        "flops_per_frame": flops,
        "param_count": params,
        "num_classes": M.NUM_CLASSES,
        "num_anchors": M.NUM_ANCHORS,
        "nattr": M.NATTR,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated variant names to build"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    entries = []
    for name, model, batch, use_ref in VARIANTS:
        if only and name not in only:
            continue
        t0 = time.time()
        text, entry = lower_variant(name, model, batch, use_ref)
        path = os.path.join(args.out_dir, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        entries.append(entry)
        print(
            f"  {name}: {len(text) / 1e6:.2f} MB HLO text, "
            f"{time.time() - t0:.1f}s"
        )

    manifest = {
        "format": "hlo-text-v1",
        "anchors_coarse": M.ANCHORS_COARSE.tolist(),
        "anchors_fine": M.ANCHORS_FINE.tolist(),
        "variants": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} variants to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
