"""L2 analysis tool: HLO op census + L1 VMEM/MXU estimates for the
shipped variants.

Used by the §Perf pass (EXPERIMENTS.md) and runnable standalone:

    cd python && python -m compile.analyze
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

import jax

from . import aot
from . import model as M
from .kernels import matmul

OP_RE = re.compile(r"\s+%?[\w.-]+ = \S+ ([\w-]+)\(")


def op_census(hlo_text: str) -> Dict[str, int]:
    """Count HLO opcodes in a module's text."""
    ops: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = OP_RE.match(line)
        if m:
            ops[m.group(1)] = ops.get(m.group(1), 0) + 1
    return ops


def fusion_health(ops: Dict[str, int]) -> List[str]:
    """Red flags for the L2 target 'fused where XLA can fuse, no
    redundant recomputation / relayouts'."""
    flags = []
    if ops.get("while", 0) > 0:
        flags.append(f"{ops['while']} while loop(s): grid not fully unrolled")
    if ops.get("transpose", 0) > 0:
        flags.append(f"{ops['transpose']} transpose(s): layout churn")
    if ops.get("copy", 0) > 0:
        flags.append(f"{ops['copy']} copy(s)")
    if ops.get("convolution", 0) > 0:
        flags.append(
            f"{ops['convolution']} convolution(s): conv escaped the Pallas GEMM"
        )
    return flags


def gemm_shapes(model_name: str, batch: int) -> List[Tuple[str, int, int, int]]:
    """(layer, M, K, N) for every GEMM the model lowers to."""
    shapes = []
    if model_name == "yolo_tiny":
        h = w = M.YOLO_INPUT[0]
        for name, k, cin, cout, stride, _act in M.YOLO_BACKBONE:
            h, w = -(-h // stride), -(-w // stride)
            shapes.append((name, batch * h * w, k * k * cin, cout))
            if name == "conv4":
                h, w = h // 2, w // 2
            if name == "conv5":
                h, w = h // 2, w // 2
        head_ch = M.NUM_ANCHORS * M.NATTR
        shapes.append(("head_coarse", batch * 36, 128, head_ch))
        shapes.append(("head_fine", batch * 144, 64, head_ch))
    else:
        h = w = M.CNN_INPUT[0]
        for name, k, cin, cout, stride, _act in M.CNN_LAYERS:
            h, w = -(-h // stride), -(-w // stride)
            shapes.append((name, batch * h * w, k * k * cin, cout))
        for name, din, dout, _act in M.CNN_DENSE:
            shapes.append((name, batch, din, dout))
    return shapes


def kernel_report(model_name: str, batch: int) -> List[dict]:
    """Per-GEMM block choice, VMEM footprint and MXU estimate."""
    rows = []
    for layer, m, k, n in gemm_shapes(model_name, batch):
        bm, bn, bk = matmul.auto_blocks(m, k, n)
        rows.append(
            {
                "layer": layer,
                "mkn": (m, k, n),
                "blocks": (bm, bn, bk),
                "grid_steps": -(-m // bm) * -(-n // bn) * -(-k // bk),
                "vmem_bytes": matmul.vmem_footprint_bytes(bm, bn, bk),
                "mxu_est": matmul.mxu_utilization_estimate(m, k, n, bm, bn, bk),
            }
        )
    return rows


def main() -> None:
    for name, model_name, batch, use_ref in aot.VARIANTS:
        fn, args = M.make_jitted(model_name, batch, use_ref=use_ref)
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        ops = op_census(text)
        flags = fusion_health(ops)
        print(f"\n== {name}: {sum(ops.values())} ops ==")
        top = sorted(ops.items(), key=lambda kv: -kv[1])[:6]
        print("  top ops:", ", ".join(f"{k}x{v}" for k, v in top))
        print("  flags:", flags if flags else "clean")
        if not use_ref:
            for row in kernel_report(model_name, batch):
                print(
                    "  {layer:12} MKN{mkn} blocks{blocks} steps={grid_steps}"
                    " vmem={vmem:.1f}MB mxu={mxu:.2f}".format(
                        layer=row["layer"],
                        mkn=row["mkn"],
                        blocks=row["blocks"],
                        grid_steps=row["grid_steps"],
                        vmem=row["vmem_bytes"] / 1e6,
                        mxu=row["mxu_est"],
                    )
                )


if __name__ == "__main__":
    main()
