"""L2: the inference models, written in JAX on top of the L1 Pallas kernels.

Two models, matching the paper's workloads:

* ``yolo_tiny`` — a structurally faithful, scaled-down YOLOv4-tiny:
  strided-conv + leaky-ReLU backbone, maxpool downsamples, and TWO
  detection heads at different scales (6x6 and 12x12 grids, 3 anchors
  each), each followed by the Pallas decode kernel. The paper's headline
  experiments run YOLOv4-tiny on video frames; this model reproduces its
  *shape* (multi-scale anchor detection, leaky-ReLU CNN) at a size a CPU
  PJRT backend serves at interactive rates. Scale-down is a documented
  substitution (DESIGN.md §2): the paper shows only frame COUNT matters
  for time/energy, so per-frame cost is a calibrated scalar anyway.

* ``simple_cnn`` — the §VI "simple CNN inference task": a small
  conv/conv/pool/dense classifier.

Weights are initialised from a fixed-seed PRNG and baked into the lowered
HLO as constants, so the rust runtime feeds ONLY the frame batch — python
never runs at serve time.

Every conv/dense goes through ``kernels.matmul.matmul_bias_act`` (the
Pallas GEMM); pure-jnp reference versions (``*_apply_ref``) exist for L2
validation and the §Perf L2 comparison.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv, decode, matmul, pool, ref

NUM_CLASSES = 20
NUM_ANCHORS = 3
NATTR = 5 + NUM_CLASSES

YOLO_INPUT = (96, 96, 3)
CNN_INPUT = (32, 32, 3)

# Anchor boxes as fractions of image size: coarse head (6x6 grid) and
# fine head (12x12 grid) — mirroring YOLOv4-tiny's two-scale layout.
ANCHORS_COARSE = np.array(
    [[0.25, 0.30], [0.40, 0.50], [0.70, 0.80]], dtype=np.float32
)
ANCHORS_FINE = np.array(
    [[0.06, 0.08], [0.12, 0.15], [0.20, 0.25]], dtype=np.float32
)

# (name, kh, cin, cout, stride, act) — the backbone; heads are 1x1 convs.
YOLO_BACKBONE = [
    ("conv1", 3, 3, 16, 2, "leaky_relu"),
    ("conv2", 3, 16, 32, 2, "leaky_relu"),
    ("conv3", 3, 32, 32, 1, "leaky_relu"),
    ("conv4", 3, 32, 32, 1, "leaky_relu"),
    # maxpool 24->12
    ("conv5", 3, 32, 64, 1, "leaky_relu"),  # 12x12x64  (fine-head source)
    # maxpool 12->6
    ("conv6", 3, 64, 128, 1, "leaky_relu"),  # 6x6x128 (coarse-head source)
]

CNN_LAYERS = [
    ("conv1", 3, 3, 16, 2, "leaky_relu"),  # 16x16x16
    ("conv2", 3, 16, 32, 2, "leaky_relu"),  # 8x8x32
    # maxpool 8->4  => flatten 512
]
CNN_DENSE = [("fc1", 512, 64, "relu"), ("fc2", 64, 10, "linear")]


def _he_init(key, shape):
    fan_in = int(np.prod(shape[:-1]))
    return jax.random.normal(key, shape, jnp.float32) * np.sqrt(2.0 / fan_in)


def init_yolo_params(seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Fixed-seed He-normal init for every tiny-YOLO weight."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jnp.ndarray] = {}
    for name, k, cin, cout, _s, _a in YOLO_BACKBONE:
        key, wk = jax.random.split(key)
        params[f"{name}_w"] = _he_init(wk, (k, k, cin, cout))
        params[f"{name}_b"] = jnp.zeros((cout,), jnp.float32)
    head_ch = NUM_ANCHORS * NATTR
    for name, cin in (("head_coarse", 128), ("head_fine", 64)):
        key, wk = jax.random.split(key)
        params[f"{name}_w"] = _he_init(wk, (1, 1, cin, head_ch))
        params[f"{name}_b"] = jnp.zeros((head_ch,), jnp.float32)
    return params


def init_cnn_params(seed: int = 0) -> Dict[str, jnp.ndarray]:
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jnp.ndarray] = {}
    for name, k, cin, cout, _s, _a in CNN_LAYERS:
        key, wk = jax.random.split(key)
        params[f"{name}_w"] = _he_init(wk, (k, k, cin, cout))
        params[f"{name}_b"] = jnp.zeros((cout,), jnp.float32)
    for name, din, dout, _a in CNN_DENSE:
        key, wk = jax.random.split(key)
        params[f"{name}_w"] = _he_init(wk, (din, dout))
        params[f"{name}_b"] = jnp.zeros((dout,), jnp.float32)
    return params


def _backbone(params, x, conv_fn, pool_fn):
    feats = {}
    for name, _k, _cin, _cout, stride, act in YOLO_BACKBONE:
        x = conv_fn(
            x, params[f"{name}_w"], params[f"{name}_b"], stride=stride, act=act
        )
        if name == "conv4":
            x = pool_fn(x)  # 24 -> 12
        if name == "conv5":
            feats["fine"] = x  # 12x12x64
            x = pool_fn(x)  # 12 -> 6
    feats["coarse"] = x  # 6x6x128
    return feats


def _heads(params, feats, conv_fn, decode_fn):
    raw_c = conv_fn(
        feats["coarse"],
        params["head_coarse_w"],
        params["head_coarse_b"],
        stride=1,
        act="linear",
    )
    raw_f = conv_fn(
        feats["fine"],
        params["head_fine_w"],
        params["head_fine_b"],
        stride=1,
        act="linear",
    )
    boxes_c = decode_fn(raw_c, jnp.asarray(ANCHORS_COARSE), NUM_CLASSES)
    boxes_f = decode_fn(raw_f, jnp.asarray(ANCHORS_FINE), NUM_CLASSES)
    return boxes_c, boxes_f


def yolo_tiny_apply(params, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas-kernel forward pass.

    Args:
      params: from ``init_yolo_params``.
      x: (B, 96, 96, 3) frames in [0, 1].

    Returns:
      (boxes_coarse (B, 108, 25), boxes_fine (B, 432, 25)).
    """
    feats = _backbone(params, x, conv.conv2d_bias_act, pool.maxpool2x2)
    return _heads(params, feats, conv.conv2d_bias_act, decode.decode_head)


def yolo_tiny_apply_ref(params, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Same network through the pure-jnp oracle kernels (L2 ground truth)."""
    feats = _backbone(params, x, ref.conv2d_bias_act_ref, ref.maxpool2x2_ref)
    return _heads(params, feats, ref.conv2d_bias_act_ref, ref.decode_head_ref)


def simple_cnn_apply(params, x) -> Tuple[jnp.ndarray]:
    """Pallas-kernel simple-CNN forward: (B, 32, 32, 3) -> (B, 10) logits."""
    for name, _k, _cin, _cout, stride, act in CNN_LAYERS:
        x = conv.conv2d_bias_act(
            x, params[f"{name}_w"], params[f"{name}_b"], stride=stride, act=act
        )
    x = pool.maxpool2x2(x)  # 8 -> 4
    x = x.reshape(x.shape[0], -1)
    for name, _din, _dout, act in CNN_DENSE:
        x = matmul.matmul_bias_act(
            x, params[f"{name}_w"], params[f"{name}_b"], act=act
        )
    return (x,)


def simple_cnn_apply_ref(params, x) -> Tuple[jnp.ndarray]:
    for name, _k, _cin, _cout, stride, act in CNN_LAYERS:
        x = ref.conv2d_bias_act_ref(
            x, params[f"{name}_w"], params[f"{name}_b"], stride=stride, act=act
        )
    x = ref.maxpool2x2_ref(x)
    x = x.reshape(x.shape[0], -1)
    for name, _din, _dout, act in CNN_DENSE:
        x = ref.matmul_bias_act_ref(
            x, params[f"{name}_w"], params[f"{name}_b"], act=act
        )
    return (x,)


def yolo_flops_per_frame() -> int:
    """Analytic FLOPs for one 96x96 frame through tiny-YOLO (manifest +
    cost-model input)."""
    h = w = YOLO_INPUT[0]
    total = 0
    for _name, k, cin, cout, stride, _act in YOLO_BACKBONE:
        h, w = -(-h // stride), -(-w // stride)
        total += conv.conv_flops(h, w, k, k, cin, cout)
        if _name == "conv4":
            h, w = h // 2, w // 2
        if _name == "conv5":
            h, w = h // 2, w // 2
    head_ch = NUM_ANCHORS * NATTR
    total += conv.conv_flops(6, 6, 1, 1, 128, head_ch)
    total += conv.conv_flops(12, 12, 1, 1, 64, head_ch)
    return total


def cnn_flops_per_frame() -> int:
    h = w = CNN_INPUT[0]
    total = 0
    for _name, k, cin, cout, stride, _act in CNN_LAYERS:
        h, w = -(-h // stride), -(-w // stride)
        total += conv.conv_flops(h, w, k, k, cin, cout)
    for _name, din, dout, _act in CNN_DENSE:
        total += 2 * din * dout
    return total


def param_count(params: Dict[str, jnp.ndarray]) -> int:
    return int(sum(np.prod(v.shape) for v in params.values()))


def make_jitted(model: str, batch: int, use_ref: bool = False):
    """Returns (fn, example_args) with weights closed over as constants —
    what aot.py lowers."""
    if model == "yolo_tiny":
        params = init_yolo_params()
        apply = yolo_tiny_apply_ref if use_ref else yolo_tiny_apply
        shape = (batch,) + YOLO_INPUT
    elif model == "simple_cnn":
        params = init_cnn_params()
        apply = simple_cnn_apply_ref if use_ref else simple_cnn_apply
        shape = (batch,) + CNN_INPUT
    else:
        raise ValueError(f"unknown model {model!r}")

    fn = functools.partial(apply, params)
    spec = jax.ShapeDtypeStruct(shape, jnp.float32)
    return fn, (spec,)
