//! Full container sweep on both devices — regenerates the data behind
//! the paper's Fig. 3a/3b/3c and writes CSVs under `results/`.
//!
//! Run: `cargo run --release --example sweep_containers`

use divide_and_save::bench::Table;
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::device::DeviceSpec;
use divide_and_save::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    for device in DeviceSpec::all() {
        let k_max = device.memory.max_containers(720);
        println!("\n## {} (1..{k_max} containers, 720 frames)", device.name);

        let mut cfg = ExperimentConfig::default();
        cfg.device = device.clone();
        cfg.containers = 1;
        let bench = run_sim(&cfg)?;

        let mut table =
            Table::new(["k", "time_s", "energy_j", "power_w", "T/T1", "E/E1", "P/P1"]);
        let mut csv = CsvWriter::new(["k", "time_s", "energy_j", "power_w", "t", "e", "p"]);
        for k in 1..=k_max {
            let mut c = cfg.clone();
            c.containers = k;
            let r = run_sim(&c)?;
            let (t, e, p) = r.normalized(&bench);
            table.row([
                k.to_string(),
                format!("{:.1}", r.time_s),
                format!("{:.1}", r.energy_j),
                format!("{:.2}", r.avg_power_w),
                format!("{t:.3}"),
                format!("{e:.3}"),
                format!("{p:.3}"),
            ]);
            csv.row([
                k.to_string(),
                r.time_s.to_string(),
                r.energy_j.to_string(),
                r.avg_power_w.to_string(),
                t.to_string(),
                e.to_string(),
                p.to_string(),
            ]);
        }
        table.print();
        let path = format!("results/fig3_{}.csv", device.name);
        csv.save(&path)?;
        println!("wrote {path}");
    }
    Ok(())
}
