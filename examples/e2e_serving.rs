//! END-TO-END DRIVER (DESIGN.md E8): loads the real AOT tiny-YOLO model
//! through PJRT and serves batched inference requests through the full
//! stack — router → splitter → k isolated container workers (own PJRT
//! runtime each, CFS-throttled) → decode (Pallas kernel output) → NMS →
//! combiner — reporting latency and throughput, plus the splittability
//! check (k=1 vs k=2 detections identical).
//!
//! REAL mode runs one job at a time (each job IS the k-way container
//! split); streaming traffic with overlapping jobs goes through the
//! event-driven serving engine on the calibrated device model — the
//! final section serves a bursty stream through `server::serve` and
//! prints the engine's JSON report.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_serving [frames] [jobs]

use divide_and_save::bench::Table;
use divide_and_save::config::{ExecMode, ExperimentConfig};
use divide_and_save::coordinator::executor::run_real;
use divide_and_save::coordinator::router::SplitPolicy;
use divide_and_save::coordinator::Coordinator;
use divide_and_save::server::{serve, ServeConfig};
use divide_and_save::util::stats::summarize;
use divide_and_save::workload::{ArrivalProcess, Video};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let frames: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let jobs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let host_cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    println!("e2e serving: {jobs} jobs x {frames} frames, host cores = {host_cores}");
    println!("model: yolo_tiny_b4 (Pallas kernels, AOT HLO, PJRT CPU)\n");

    let mk_cfg = |k: usize| {
        let mut c = ExperimentConfig::default();
        c.mode = ExecMode::Real;
        c.containers = k;
        c.video = Video::with_frames("e2e", frames, 24.0);
        c.variant = "yolo_tiny_b4".to_string();
        c
    };

    // --- splittability proof: identical detections for k=1 and k=2 ----
    let r1 = run_real(&mk_cfg(1))?;
    let r2 = run_real(&mk_cfg(2))?;
    let count = |r: &divide_and_save::coordinator::ExperimentResult| {
        r.segments.iter().map(|s| s.detections.len()).sum::<usize>()
    };
    assert_eq!(count(&r1), count(&r2), "splitting changed the detections!");
    println!(
        "splittability check: k=1 and k=2 both produce {} detections over {frames} frames ✓\n",
        count(&r1)
    );

    // --- serve batched jobs at each k, report latency/throughput ------
    let ks: Vec<usize> = if host_cores >= 4 {
        vec![1, 2, 4]
    } else if host_cores >= 2 {
        vec![1, 2]
    } else {
        vec![1, 2] // 1-core host: k=2 shows the isolation overhead honestly
    };

    let mut table = Table::new([
        "k", "jobs", "mean_lat_s", "p95_lat_s", "frames/s", "dets/job", "energy_j(model)",
    ]);
    for &k in &ks {
        let mut latencies = Vec::new();
        let mut dets = 0usize;
        let mut energy = 0.0;
        let t0 = std::time::Instant::now();
        for _ in 0..jobs {
            let r = run_real(&mk_cfg(k))?;
            latencies.push(r.time_s);
            dets += r.total_detections;
            energy += r.energy_j;
        }
        let wall = t0.elapsed().as_secs_f64();
        let s = summarize(&latencies);
        table.row([
            k.to_string(),
            jobs.to_string(),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.p95),
            format!("{:.1}", (jobs * frames) as f64 / wall),
            format!("{}", dets / jobs),
            format!("{energy:.1}"),
        ]);
    }
    table.print();
    println!("\n(energy is modeled from the calibrated TX2 power curve driven by the");
    println!(" measured per-container busy time — this host has no power rails.)");

    // --- streaming traffic through the event-driven engine (SIM) -----
    println!("\nconcurrent serving engine, bursty MMPP stream (calibrated TX2 model):");
    let mut coordinator =
        Coordinator::new(ExperimentConfig::default(), SplitPolicy::Fixed(2));
    let report = serve(
        &mut coordinator,
        &ServeConfig {
            jobs: 24,
            arrival: Some(ArrivalProcess::Mmpp {
                calm_rate_per_s: 0.01,
                burst_rate_per_s: 0.2,
                mean_calm_s: 120.0,
                mean_burst_s: 30.0,
            }),
            frames_per_job: frames,
            max_concurrent_jobs: 2,
            seed: 17,
            ..Default::default()
        },
    )?;
    println!("{}", report.to_json().pretty());
    Ok(())
}
