//! Quickstart: the paper's method in ~30 lines of API.
//!
//! Splits the paper's 30-second video (720 frames) across 4 containers
//! on a simulated Jetson TX2 and compares time / energy / power against
//! the single-container benchmark — Fig. 3's headline cells.
//!
//! Run: `cargo run --release --example quickstart`

use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;

fn main() -> anyhow::Result<()> {
    // The benchmark: one container, all four TX2 cores.
    let mut cfg = ExperimentConfig::default();
    cfg.containers = 1;
    let benchmark = run_sim(&cfg)?;
    println!(
        "benchmark (1 container):  {:6.1} s  {:6.1} J  {:5.2} W",
        benchmark.time_s, benchmark.energy_j, benchmark.avg_power_w
    );

    // Divide and save: 4 containers, 1 core + 180 frames each.
    cfg.containers = 4;
    let split = run_sim(&cfg)?;
    println!(
        "divide-and-save (k=4):    {:6.1} s  {:6.1} J  {:5.2} W",
        split.time_s, split.energy_j, split.avg_power_w
    );

    let (t, e, p) = split.normalized(&benchmark);
    println!("\nversus benchmark:");
    println!("  time   {:5.1}% ({t:.3}x)   paper: -25%", (t - 1.0) * 100.0);
    println!("  energy {:5.1}% ({e:.3}x)   paper: -15%", (e - 1.0) * 100.0);
    println!("  power  {:+5.1}% ({p:.3}x)   paper: +13%", (p - 1.0) * 100.0);
    Ok(())
}
