//! Battery-lifetime scenario: the paper's intro motivation ("edge
//! computing devices are often powered by batteries") made concrete.
//!
//! A battery-powered TX2 processes 30-second videos back-to-back. How
//! many videos per charge, and how much longer does the battery last,
//! under each split policy and power mode?
//!
//! Run: `cargo run --release --example battery_lifetime`

use divide_and_save::bench::Table;
use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::executor::run_sim;
use divide_and_save::device::dvfs::PowerMode;
use divide_and_save::device::DeviceSpec;
use divide_and_save::energy::Battery;

fn main() -> anyhow::Result<()> {
    let battery = Battery::pack_50wh();
    println!(
        "battery: {:.0} Wh pack, {:.0}% usable -> {:.0} kJ\n",
        battery.capacity_wh,
        battery.usable_frac * 100.0,
        battery.usable_j() / 1e3
    );

    for base in [DeviceSpec::tx2(), DeviceSpec::orin()] {
        println!("## {}", base.name);
        let mut table = Table::new([
            "mode", "k", "time/video", "energy/video", "videos/charge", "hours busy",
        ]);
        for mode in PowerMode::modes_for(&base) {
            let dev = mode.apply(&base);
            for k in [1usize, dev.cores as usize] {
                let mut cfg = ExperimentConfig::default();
                cfg.device = dev.clone();
                cfg.containers = k;
                let r = run_sim(&cfg)?;
                let videos = battery.jobs_supported(r.energy_j, r.avg_power_w);
                table.row([
                    mode.name.to_string(),
                    k.to_string(),
                    format!("{:.0} s", r.time_s),
                    format!("{:.0} J", r.energy_j),
                    videos.to_string(),
                    format!("{:.1}", videos as f64 * r.time_s / 3600.0),
                ]);
            }
        }
        table.print();
        println!();
    }
    println!("divide-and-save processes more videos per charge in every mode —");
    println!("the energy saving compounds with DVFS instead of competing with it.");
    Ok(())
}
