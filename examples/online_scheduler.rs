//! The paper's future-work scheduler, working end to end: jobs with
//! different tasks arrive at the coordinator; the online optimizer
//! probes a short prefix, fits the Table II convex model family, picks
//! the optimal container count per (device, task), caches the decision
//! and serves the rest of the workload with it.
//!
//! The serving engine consults the same optimizer under an
//! *availability cap* (a `PlanRequest` with a partial grant): when
//! other jobs already hold part of the device, the split is sized to
//! the cores and memory actually free — the last section shows the
//! decision shrinking with the grant.
//!
//! Run: `cargo run --release --example online_scheduler`

use divide_and_save::config::ExperimentConfig;
use divide_and_save::coordinator::router::SplitPolicy;
use divide_and_save::coordinator::{
    Coordinator, InferenceJob, OnlineOptimizer, OptimizeObjective,
};
use divide_and_save::device::DeviceSpec;
use divide_and_save::workload::{TaskProfile, Video};

fn main() -> anyhow::Result<()> {
    for device in DeviceSpec::all() {
        println!("\n## {} — online optimal-k scheduling", device.name);
        let mut base = ExperimentConfig::default();
        base.device = device.clone();

        let optimizer = OnlineOptimizer {
            objective: OptimizeObjective::Weighted(0.5),
            ..Default::default()
        };
        let mut coordinator = Coordinator::new(base.clone(), SplitPolicy::Online(optimizer));
        let mut naive = Coordinator::new(base, SplitPolicy::Fixed(1));

        let mut saved_time = 0.0;
        let mut saved_energy = 0.0;
        for (id, task) in [
            (0u64, TaskProfile::yolo_tiny()),
            (1, TaskProfile::simple_cnn()),
            (2, TaskProfile::yolo_tiny()),
            (3, TaskProfile::yolo_tiny()),
        ] {
            let job = InferenceJob {
                id,
                video: Video::paper_default(),
                task: task.clone(),
            };
            let smart = coordinator.submit(job.clone())?;
            let dumb = naive.submit(job)?;
            saved_time += dumb.result.time_s - smart.result.time_s;
            saved_energy += dumb.result.energy_j - smart.result.energy_j;
            println!(
                "  job {id} ({:<10}): k={} -> {:6.1}s {:6.1}J   (1 container: {:6.1}s {:6.1}J)",
                task.name,
                smart.containers_used,
                smart.result.time_s,
                smart.result.energy_j,
                dumb.result.time_s,
                dumb.result.energy_j,
            );
        }
        for (key, d) in coordinator.decisions() {
            println!("  cached decision {key}: k={} model {}", d.best_k, d.model.describe());
        }
        println!("  total saved: {saved_time:.1} s, {saved_energy:.1} J across 4 jobs");

        // --- availability-constrained decisions (the engine's view) ---
        let mem = device.memory.available_mib();
        println!("  availability-constrained k (what a half-busy device gets):");
        for frac in [1.0, 0.5, 0.25] {
            let avail = (device.cores * frac).max(1.0);
            let job = InferenceJob {
                id: 99,
                video: Video::paper_default(),
                task: TaskProfile::yolo_tiny(),
            };
            let req = coordinator.request_for(&job).with_grant(avail, mem * frac);
            let plan = coordinator.plan(&req)?;
            println!("    {avail:4.1} cores free -> k={}", plan.k);
        }
    }
    Ok(())
}
